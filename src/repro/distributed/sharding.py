"""Sharding rules: PartitionSpec per parameter/cache leaf, by pytree path.

Conventions (MaxText-style logical axes, resolved per-leaf with divisibility
checks):
  * "model" axis  — tensor parallel: FFN hidden (d_ff), attention heads,
    vocab, MoE experts, SSM inner dim.
  * "data" axis   — batch parallel + FSDP: the d_model (or other non-TP) dim
    of each weight is sharded over data as ZeRO-style FSDP; optimizer moments
    inherit the same specs (ZeRO-1 comes for free).
  * "pod" axis    — composes with "data" for batch/FSDP sharding across pods.

A candidate dim is only sharded when its size divides the axis size; otherwise
the next candidate is tried, else the dim stays replicated. Leading stacked
scan dims (layer groups) are never sharded.
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P


# -- jax version compatibility ------------------------------------------------
# The mesh construction API moved between jax releases: AbstractMesh switched
# from a tuple of (name, size) pairs to positional (sizes, names), AxisType
# only exists on newer jax, and make_mesh only grew axis_types later. These
# helpers are the single place the repo adapts; call sites (launch/mesh.py,
# tests, subprocess scripts) stay version-agnostic.

def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]) -> AbstractMesh:
    """AbstractMesh(sizes, names) across jax versions."""
    try:                                   # newer jax: positional (sizes, names)
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:                      # jax <= 0.4.x: ((name, size), ...)
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def auto_axis_types(n: int):
    """(AxisType.Auto,) * n where supported, else None (older jax default)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return None if axis_type is None else (axis_type.Auto,) * n


def make_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """jax.make_mesh with Auto axis types where the installed jax supports it."""
    types = auto_axis_types(len(tuple(axis_names)))
    if types is not None:
        try:
            return jax.make_mesh(tuple(axis_sizes), tuple(axis_names),
                                 axis_types=types)
        except TypeError:
            pass
    return jax.make_mesh(tuple(axis_sizes), tuple(axis_names))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(mesh: Mesh, batch_size: int, ndim: int) -> P:
    """Shard the leading batch dim over (pod, data) when divisible."""
    axes = dp_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    first = axes if batch_size % total == 0 else (
        ("data",) if batch_size % mesh.shape["data"] == 0 else None)
    return P(first, *([None] * (ndim - 1)))


# rule table: (path regex, [(axis_kind, candidate dims from the END)...])
# dims are negative indices; first divisible candidate wins.
_RULES: List[Tuple[str, List[Tuple[str, Sequence[int]]]]] = [
    (r"embed/embedding$",        [("model", (-2,)), ("data", (-1,))]),
    (r"embed/lm_head$",          [("model", (-1,)), ("data", (-2,))]),
    (r"projector/w[12]$",        [("model", (-1,)), ("data", (-2,))]),
    (r"frontend_proj$",          [("model", (-1,)), ("data", (-2,))]),
    # attention
    (r"(mixer|attn|self_attn|cross_attn)/w[qkv]$", [("model", (-1,)), ("data", (-2,))]),
    (r"(mixer|attn|self_attn|cross_attn)/wo$",     [("model", (-2,)), ("data", (-1,))]),
    (r"(mixer|attn|self_attn|cross_attn)/b[qkv]$", [("model", (-1,))]),
    # dense FFN
    (r"ffn/w_(up|gate)$",        [("model", (-1,)), ("data", (-2,))]),
    (r"ffn/w_down$",             [("model", (-2,)), ("data", (-1,))]),
    # MoE: experts first, then expert-ffn dim
    (r"ffn/router$",             [("data", (-2,))]),
    (r"ffn/w_(up|gate)$",        [("model", (-1,)), ("data", (-2,))]),   # covered above
    # mamba
    (r"mixer/in_proj$",          [("model", (-1,)), ("data", (-2,))]),
    (r"mixer/conv_w$",           [("model", (-1,))]),
    (r"mixer/conv_b$",           [("model", (-1,))]),
    (r"mixer/x_proj$",           [("model", (-2,))]),
    (r"mixer/dt_proj$",          [("model", (-1,))]),
    (r"mixer/dt_bias$",          [("model", (-1,))]),
    (r"mixer/A_log$",            [("model", (-2,))]),
    (r"mixer/D$",                [("model", (-1,))]),
    (r"mixer/out_proj$",         [("model", (-2,)), ("data", (-1,))]),
    # xLSTM
    (r"mixer/w[qkvo]$|mixer/w_o$", [("model", (-1,)), ("data", (-2,))]),
    (r"mixer/w_[if]$",           [("data", (-2,))]),
    (r"mixer/(w_z|w_i|w_f)$",    [("data", (-2,))]),
    (r"mixer/r_[zifo]$",         [("model", (-3,))]),
    (r"mixer/b_[zifo]$",         []),
]

# MoE expert tensors get a dedicated rule applied before the generic ffn ones.
_MOE_RULES: List[Tuple[str, List[Tuple[str, Sequence[int]]]]] = [
    (r"ffn/w_(up|gate)$", [("model", (-3, -1)), ("data", (-1, -2))]),   # [E, d, f]
    (r"ffn/w_down$",      [("model", (-3, -2)), ("data", (-2, -1))]),   # [E, f, d]
]


def _leaf_path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)


def _spec_for(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
              is_moe_expert: bool) -> P:
    ndim = len(shape)
    if ndim == 0:
        return P()
    assignment: dict[int, str] = {}

    def try_assign(axis_name: str, candidates: Sequence[int]) -> None:
        if axis_name not in mesh.axis_names:
            return
        size = mesh.shape[axis_name]
        for c in candidates:
            dim = ndim + c if c < 0 else c
            if dim < 0 or dim >= ndim or dim in assignment:
                continue
            if shape[dim] % size == 0 and shape[dim] >= size:
                assignment[dim] = axis_name
                return

    rules = _MOE_RULES + _RULES if is_moe_expert else _RULES
    matched = False
    for pattern, axes in rules:
        if re.search(pattern, path_str):
            for axis_name, candidates in axes:
                try_assign(axis_name, candidates)
            matched = True
            break
    if not matched and ndim >= 2:
        try_assign("model", (-1, -2))
        try_assign("data", (-2, -1))
    spec = [assignment.get(d) for d in range(ndim)]
    return P(*spec)


def param_specs(params_shape: Any, mesh: Mesh,
                replicate_below: int = 0) -> Any:
    """PartitionSpec pytree matching an eval_shape'd params/opt-state tree.

    replicate_below: leaves with fewer elements are fully replicated — at
    small model scale per-layer TP all-reduces cost more than the redundant
    compute they save (§Perf xlstm finding).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        ps = _leaf_path_str(path)
        if replicate_below and int(np.prod(leaf.shape)) < replicate_below:
            specs.append(P(*([None] * len(leaf.shape))))
            continue
        is_moe = bool(re.search(r"ffn/(w_(up|gate|down))$", ps)) and len(leaf.shape) >= 3
        specs.append(_spec_for(ps, tuple(leaf.shape), mesh, is_moe))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_specs(cache_shape: Any, mesh: Mesh, batch_size: int,
                shard_seq: bool = False, no_model: bool = False) -> Any:
    """Decode-cache sharding: batch over data axes; KV-heads/inner over model.

    Cache leaves (after the stacked layer-group dim) are:
      KVCache k/v [G, B, S, KV, hd]; SWACache pos [G, B, W];
      Mamba conv [G, B, dc-1, di] / ssm [G, B, di, N];
      mLSTM C [G, B, H, hd, hd], n [G, B, H, hd], m [G, B, H]; sLSTM [G, B, H, hd].
    """
    axes = dp_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    b_axes: Optional[Tuple[str, ...]] = axes if batch_size % total == 0 else (
        ("data",) if batch_size % mesh.shape["data"] == 0 else None)
    model_size = mesh.shape["model"]

    def spec(path, leaf) -> P:
        shape = leaf.shape
        ndim = len(shape)
        path_str = _leaf_path_str(path)
        # find batch dim: dim 1 for stacked caches ([G, B, ...]); dim 0 for
        # unstacked (encdec DecoderCache mem_k: [L, B, F, KV, hd] also stacked)
        out = [None] * ndim
        bdim = 1 if ndim >= 2 else 0
        if ndim >= 2 and shape[bdim] == batch_size and b_axes:
            out[bdim] = b_axes
        if no_model:        # replicated-compute variant (§Perf C3): batch only
            return P(*out)
        leaf_name = path_str.split("/")[-1]
        is_kv = leaf_name in ("k", "v") and ndim == 5
        is_scale = leaf_name.endswith("_scale") and ndim == 4   # int8 KV scales
        if is_scale:
            if shard_seq and shape[2] % model_size == 0:
                out[2] = "model"
            elif shape[3] % model_size == 0:
                out[3] = "model"
            return P(*out)
        if shard_seq and is_kv and shape[2] % model_size == 0:
            # §Perf variant: shard the KV SEQUENCE dim — attention reduces over
            # it, so SPMD emits small softmax-stat all-reduces instead of
            # resharding the whole cache (distributed flash-decode semantics).
            out[2] = "model"
            return P(*out)
        if ndim <= 3:                      # small bookkeeping leaves: batch only
            return P(*out)
        # model axis on a heads-like dim when divisible (prefer KV over hd)
        for d in ([ndim - 2, ndim - 1] if ndim >= 4 else [ndim - 1]):
            if d <= bdim:
                continue
            if is_kv and d == 2:           # never the sequence dim here
                continue
            if shape[d] % model_size == 0 and shape[d] >= model_size:
                out[d] = "model"
                break
        return P(*out)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(treedef, [spec(p, l) for p, l in flat])


def named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
