"""granite-34b — dense MQA (kv=1) code model, llama-arch [arXiv:2405.04324]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-34b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",        # granite code models use GELU MLPs
    norm="layernorm",
    rope_theta=1e5,
)
