"""internlm2-20b — dense GQA decoder [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-20b",
    family="dense",
    source="arXiv:2403.17297",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    activation="silu",
    norm="rmsnorm",
    rope_theta=1e6,
)
