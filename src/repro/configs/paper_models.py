"""The paper's own evaluation models (Table 3) — ReLU-sparse variants.

Used by the benchmarks reproducing the paper's tables/figures. Neuron counts
match Table 3 (neurons per FFN block; 2 linear layers in OPT, 3 in others).
"""
from repro.configs.base import ModelConfig

OPT_350M = ModelConfig(
    arch_id="opt-350m", family="dense", source="arXiv:2205.01068 (paper Table 3)",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=50272, activation="relu", norm="layernorm", rope_theta=1e4,
)

OPT_1_3B = ModelConfig(
    arch_id="opt-1.3b", family="dense", source="arXiv:2205.01068 (paper Table 3)",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=50272, activation="relu", norm="layernorm", rope_theta=1e4,
)

OPT_6_7B = ModelConfig(
    arch_id="opt-6.7b", family="dense", source="arXiv:2205.01068 (paper Table 3)",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=16384,
    vocab_size=50272, activation="relu", norm="layernorm", rope_theta=1e4,
)

LLAMA2_7B_RELU = ModelConfig(
    arch_id="llama2-7b-relu", family="dense", source="arXiv:2307.09288 + ProSparse relu variant (paper Table 3)",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab_size=32000, activation="relu", norm="rmsnorm", rope_theta=1e4,
)

MISTRAL_7B_RELU = ModelConfig(
    arch_id="mistral-7b-relu", family="dense", source="arXiv:2310.06825 + TurboSparse relu variant (paper Table 3)",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, activation="relu", norm="rmsnorm", rope_theta=1e4,
)

# Paper Table 3 sparsity ratios (fraction of neurons ACTIVATED per token).
PAPER_SPARSITY = {
    "opt-350m": 0.0949,
    "opt-1.3b": 0.0409,
    "opt-6.7b": 0.0328,
    "llama2-7b-relu": 0.1388,
    "mistral-7b-relu": 0.6052,
}

# Neurons per FFN block and matrices per bundle (Table 3 footnote).
PAPER_NEURONS = {
    "opt-350m": (4096, 2),
    "opt-1.3b": (8192, 2),
    "opt-6.7b": (16384, 2),
    "llama2-7b-relu": (11008, 3),
    "mistral-7b-relu": (14336, 3),
}

PAPER_MODELS = {
    m.arch_id: m for m in (OPT_350M, OPT_1_3B, OPT_6_7B, LLAMA2_7B_RELU, MISTRAL_7B_RELU)
}
