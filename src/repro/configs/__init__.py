"""Config registry: the 10 assigned architectures + the paper's own models."""
from __future__ import annotations

from typing import Dict

from repro.configs import (granite_3_2b, granite_34b, granite_moe_1b_a400m,
                           granite_moe_3b_a800m, internlm2_20b, internvl2_26b,
                           jamba_1_5_large_398b, qwen2_7b, seamless_m4t_medium,
                           xlstm_125m)
from repro.configs.base import (INPUT_SHAPES, InputShape, MambaConfig, MoEConfig,
                                ModelConfig, reduced_config)
from repro.configs.paper_models import (PAPER_MODELS, PAPER_NEURONS,
                                        PAPER_SPARSITY)

ASSIGNED_CONFIGS: Dict[str, ModelConfig] = {
    c.CONFIG.arch_id: c.CONFIG
    for c in (
        internlm2_20b, internvl2_26b, granite_moe_1b_a400m, granite_34b,
        granite_3_2b, granite_moe_3b_a800m, jamba_1_5_large_398b, xlstm_125m,
        seamless_m4t_medium, qwen2_7b,
    )
}

ALL_CONFIGS: Dict[str, ModelConfig] = {**ASSIGNED_CONFIGS, **PAPER_MODELS}


def get_config(arch_id: str, reduced: bool = False, **overrides) -> ModelConfig:
    if arch_id not in ALL_CONFIGS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ALL_CONFIGS)}")
    cfg = ALL_CONFIGS[arch_id]
    if reduced:
        cfg = reduced_config(cfg, **overrides)
    elif overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


__all__ = [
    "ModelConfig", "MoEConfig", "MambaConfig", "InputShape", "INPUT_SHAPES",
    "ASSIGNED_CONFIGS", "ALL_CONFIGS", "PAPER_MODELS", "PAPER_SPARSITY",
    "PAPER_NEURONS", "get_config", "reduced_config",
]
