"""internvl2-26b — VLM: InternViT-6B frontend (stub) + InternLM2-20B backbone
[arXiv:2404.16821].

Per the assignment, the vision encoder is a STUB: `input_specs` supplies
pre-projector patch features [B, n_prefix_tokens, d_frontend]; the framework
implements the MLP projector + the full language backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    activation="silu",
    norm="rmsnorm",
    rope_theta=1e6,
    d_frontend=3200,          # InternViT-6B hidden size
    n_prefix_tokens=256,      # image tokens per request (pixel-unshuffled ViT patches)
)
