"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

Layer layout: period-8 blocks with one attention layer per block (position 4),
seven Mamba layers; MoE replaces the dense FFN every other layer (period 2),
16 experts top-2 — matching the Jamba block design.
"""
from repro.configs.base import MambaConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    activation="silu",
    norm="rmsnorm",
    rope_theta=1e6,
    attn_period=8,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, moe_period=2),
)
