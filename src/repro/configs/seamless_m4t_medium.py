"""seamless-m4t-medium — encoder-decoder, multimodal (speech/text)
[arXiv:2308.11596].

The audio frontend (mel-spectrogram + conv feature extractor) is a STUB per
the assignment: `input_specs` provides precomputed frame embeddings
[B, n_prefix_tokens, d_frontend] consumed by the 12-layer text/unit encoder;
the 12-layer decoder cross-attends to encoder output. n_layers counts the
decoder stack; n_enc_layers the encoder stack.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    activation="relu",
    norm="layernorm",
    rope_theta=1e4,
    n_enc_layers=12,
    d_frontend=1024,          # w2v-BERT conv frontend output dim
    n_prefix_tokens=1024,     # encoder frames per request
)
