"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

Block pattern follows the xLSTM[7:1] style at small scale: positions 3 and 9
are sLSTM, the rest mLSTM. d_ff=0: xLSTM blocks carry their own up/down
projections instead of a separate FFN.
"""
from repro.configs.base import ModelConfig

_PATTERN = tuple("slstm" if i in (3, 9) else "mlstm" for i in range(12))

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    activation="gelu",
    norm="layernorm",
    block_pattern=_PATTERN,
)
