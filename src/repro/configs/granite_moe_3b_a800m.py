"""granite-moe-3b-a800m — MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    activation="silu",
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=1e4,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, moe_period=1),
)
