"""Model configuration schema + input-shape registry.

Every assigned architecture gets one file in this package defining `CONFIG`
(the exact assigned hyper-parameters, source cited) and `reduced()` (a tiny
same-family variant for CPU smoke tests). `repro.configs.get_config(arch_id)`
resolves either.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    moe_period: int = 1        # every `period`-th layer is MoE (1 = all layers)
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    source: str                 # citation from the assignment
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    activation: str = "silu"    # relu | silu | gelu | relu2
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    max_seq_len: int = 524_288
    sliding_window: int = 8_192   # SWA window used only by the long_500k decode path
    # -- family extensions --
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    attn_period: int = 0        # hybrid: one attention layer per `attn_period` layers (0 = all attn)
    block_pattern: Tuple[str, ...] = ()   # ssm (xlstm): per-layer block kinds, cycled
    n_enc_layers: int = 0       # audio enc-dec: encoder depth (n_layers = decoder depth)
    d_frontend: int = 0         # vlm/audio: stub frontend embedding dim (pre-projector)
    n_prefix_tokens: int = 0    # vlm: image tokens per sequence; audio: encoder frames
    # -- numerics --
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    # -- perf variants (§Perf hillclimbing; defaults = paper-faithful baseline) --
    flash_triangular: bool = False   # causal flash skips fully-masked KV blocks
    flash_q_chunk: int = 1024
    flash_k_chunk: int = 1024
    serve_sparse: bool = False       # decode FFN via predictor + segment top-k
    sparse_seg: int = 128            # neuron segment width (kernels/sparse_ffn)
    sparse_frac: float = 0.15        # fraction of segments gathered per step
    kv_quant: bool = False           # int8 KV cache (halves decode KV streaming)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    def pdtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind: 'attn' | 'mamba' | 'slstm' | 'mlstm'."""
        if self.family == "ssm":
            pat = self.block_pattern or ("mlstm",)
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.family == "hybrid" and self.attn_period > 0:
            return tuple(
                "attn" if i % self.attn_period == self.attn_period // 2 else "mamba"
                for i in range(self.n_layers)
            )
        return ("attn",) * self.n_layers

    def ffn_kinds(self) -> Tuple[str, ...]:
        """Per-layer FFN kind: 'dense' | 'moe' | 'none'."""
        if self.d_ff == 0 and self.moe is None:
            return ("none",) * self.n_layers
        if self.moe is None:
            return ("dense",) * self.n_layers
        p = self.moe.moe_period
        return tuple("moe" if i % p == p - 1 else "dense" for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs accounting)."""
        d, L = self.d_model, self.n_layers
        hd, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        kinds, ffns = self.layer_kinds(), self.ffn_kinds()
        for kind, ffn in zip(kinds, ffns):
            if kind == "attn":
                total += d * hd * (H + 2 * KV) + H * hd * d
            elif kind == "mamba":
                m = self.mamba or MambaConfig()
                di = m.expand * d
                total += d * di * 2 + di * m.d_conv + di * (2 * m.d_state + 2) + di * d
            elif kind in ("mlstm", "slstm"):
                total += 4 * d * d + 3 * self.n_heads * self.head_dim * d
            if ffn == "dense":
                total += 3 * d * self.d_ff if self.activation != "relu" or True else 0
            elif ffn == "moe":
                assert self.moe is not None
                total += self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        if self.is_encdec:
            for _ in range(self.n_enc_layers):
                total += d * hd * (H + 2 * KV) + H * hd * d + 3 * d * self.d_ff
            total += L * (d * hd * (H + 2 * KV) + H * hd * d)  # cross-attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        n_moe = sum(1 for f in self.ffn_kinds() if f == "moe")
        full = n_moe * self.moe.n_experts * 3 * self.d_model * self.moe.d_ff_expert
        act = n_moe * self.moe.top_k * 3 * self.d_model * self.moe.d_ff_expert
        return total - full + act


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family variant: 2 layers, d_model<=512, <=4 experts."""
    changes = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=4_096,
        sliding_window=64,
        remat=False,
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 128),
            capacity_factor=cfg.moe.capacity_factor,
            moe_period=min(cfg.moe.moe_period, 2),
        )
    if cfg.family == "hybrid":
        changes["n_layers"] = 4
        changes["attn_period"] = min(cfg.attn_period, 4) or 4
    if cfg.n_enc_layers:
        changes["n_enc_layers"] = 2
    if cfg.d_frontend:
        changes["d_frontend"] = min(cfg.d_frontend, 128)
        changes["n_prefix_tokens"] = min(cfg.n_prefix_tokens, 16)
    # keep head_dim divisibility
    d = changes["d_model"]
    changes["n_heads"] = max(1, min(changes["n_heads"], d // 32))
    changes["n_kv_heads"] = max(1, min(changes["n_kv_heads"], changes["n_heads"]))
    while d % changes["n_heads"]:
        changes["n_heads"] -= 1
    while changes["n_heads"] % changes["n_kv_heads"]:
        changes["n_kv_heads"] -= 1
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
