"""Roofline analysis per (arch x input shape) on the single-pod mesh.

Three terms per case (v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI):

    compute_s    = HLO_FLOPs / (chips x peak)
    memory_s     = HLO_bytes / (chips x HBM_bw)
    collective_s = collective_bytes / (chips x link_bw)

ACCOUNTING NOTE (documented in EXPERIMENTS.md): XLA-CPU's cost_analysis counts
while-loop bodies ONCE (verified empirically), so raw compiled numbers
understate scanned-layer programs by the trip count. The roofline therefore
uses ANALYTIC terms derived from the model config and shapes — the exact
napkin-math the perf methodology calls for — including known compiled-graph
waste (masked flash-attention blocks compute the full rectangle = 2x causal
FLOPs; MoE capacity factor = 1.25x active FLOPs). The raw parsed values are
carried alongside for before/after deltas within an identical program shape.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link
CHIPS = 256                  # single-pod roofline table
BYTES = 2                    # bf16


def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for k in cfg.layer_kinds() if k == "attn")


def _d_attn(cfg: ModelConfig) -> int:
    return cfg.n_heads * cfg.head_dim


def analytic_terms(cfg: ModelConfig, shape: InputShape, swa: bool,
                   mesh_model: int = 16, mesh_dp: int = 16) -> Dict[str, float]:
    """Global FLOPs / bytes / per-chip collective bytes for one case."""
    B, S = shape.global_batch, shape.seq_len
    N_act = cfg.active_param_count()
    N_tot = cfg.param_count()
    L_attn = _attn_layers(cfg)
    d_att = _d_attn(cfg)
    L = cfg.n_layers
    d = cfg.d_model
    cf_waste = (cfg.moe.capacity_factor if cfg.moe else 1.0)
    dp_local_B = max(B // mesh_dp, 1)

    if shape.kind == "train":
        tokens = B * S
        model_flops = 6 * N_act * tokens + 6 * L_attn * B * S * S * d_att
        # compiled waste: flash computes full rectangle (2x causal) + MoE cf
        hlo_flops = 6 * N_act * tokens * cf_waste + 12 * L_attn * B * S * S * d_att
        # bytes: params read fwd+bwd, grads written, opt moments rw, activations
        hlo_bytes = (N_tot * BYTES * 4 + N_tot * 4 * 2
                     + 24 * tokens * d * L * BYTES)
        # collectives per chip: megatron 2 AR/layer fwd + 2 bwd of [B_loc,S,d]
        # + grad reduce over dp of params/model_shard
        ar_act = 4 * L * dp_local_B * S * d * BYTES * 2
        ar_grad = 2 * (N_tot / mesh_model) * BYTES
        coll_per_chip = ar_act + ar_grad
    elif shape.kind == "prefill":
        tokens = B * S
        model_flops = 2 * N_act * tokens + 2 * L_attn * B * S * S * d_att
        hlo_flops = 2 * N_act * tokens * cf_waste + 4 * L_attn * B * S * S * d_att
        hlo_bytes = (N_tot * BYTES
                     + 2 * L_attn * B * S * cfg.n_kv_heads * cfg.head_dim * BYTES
                     + 8 * tokens * d * L * BYTES)
        coll_per_chip = 2 * L * dp_local_B * S * d * BYTES * 2
    else:  # decode: ONE token with a seq_len-deep cache
        S_eff = min(S, cfg.sliding_window) if swa else S
        attn_flops = 4 * L_attn * B * S_eff * d_att
        model_flops = 2 * N_act * B + attn_flops
        hlo_flops = 2 * N_act * B * cf_waste + attn_flops
        kv_bytes = 2 * L_attn * B * S_eff * cfg.n_kv_heads * cfg.head_dim * BYTES
        hlo_bytes = N_act * BYTES + kv_bytes * 2   # read + rewrite (observed copy)
        coll_per_chip = 2 * L * dp_local_B * 1 * d * BYTES * 2

    return {
        "model_flops": float(model_flops),
        "hlo_flops_est": float(hlo_flops),
        "hlo_bytes_est": float(hlo_bytes),
        "coll_bytes_per_chip": float(coll_per_chip),
        "compute_s": hlo_flops / (CHIPS * PEAK_FLOPS),
        "memory_s": hlo_bytes / (CHIPS * HBM_BW),
        "collective_s": coll_per_chip / LINK_BW,
        "useful_ratio": model_flops / hlo_flops,
    }


def sparse_ffn_segment_terms(batch: int, k_active: int, n_mats: int,
                             d_model: int, weight_itemsize: int = 4,
                             seg_size: int = 128) -> Dict[str, float]:
    """Single-chip roofline terms for ONE fused sparse-FFN segment call
    (kernels/sparse_ffn.py): the decode-step hot path after placement.

    The kernel streams ceil(k/seg) weight segments per matrix from HBM into
    VMEM (int8 tiles quarter those bytes), one f32 scale/membership row per
    segment (always present — it carries the activated-union mask even for
    f32 payloads), revisits the [B, d] activation block once per segment,
    and writes one [B, d] output. FLOPs count the full covered span
    (pad neurons inside a segment still multiply, against zeroed scales).
    """
    n_seg = -(-k_active // seg_size)
    covered = n_seg * seg_size
    flops = 2.0 * batch * covered * n_mats * d_model
    weight_bytes = float(covered * n_mats * d_model * weight_itemsize)
    scale_bytes = float(covered * 4)
    act_bytes = float(batch * d_model * 4 * (n_seg + 1))
    hlo_bytes = weight_bytes + scale_bytes + act_bytes
    return {
        "flops": flops,
        "weight_bytes": weight_bytes,
        "scale_bytes": scale_bytes,
        "hlo_bytes": hlo_bytes,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hlo_bytes / HBM_BW,
        "intensity": flops / hlo_bytes,
    }


def sparse_ffn_rows(batch: int = 8, k_active: int = 2048, n_mats: int = 2,
                    d_model: int = 2048) -> List[Tuple[str, float, str]]:
    """`sparse_ffn_segments` roofline rows: f32 vs int8 weight streaming for
    the same activated union. Decode batches are tiny, so the kernel lives on
    the memory roof — quantised tiles cut the dominant term ~4x, which is why
    the fused in-kernel dequant (never materialising f32 rows) is the win."""
    out = []
    for tag, itemsize in (("f32", 4), ("int8", 1)):
        t = sparse_ffn_segment_terms(batch, k_active, n_mats, d_model,
                                     weight_itemsize=itemsize)
        dominant = "memory" if t["memory_s"] >= t["compute_s"] else "compute"
        out.append((
            f"roofline/sparse_ffn_segments/{tag}",
            max(t["compute_s"], t["memory_s"]) * 1e6,
            f"dominant={dominant} compute={t['compute_s']*1e6:.2f}us "
            f"memory={t['memory_s']*1e6:.2f}us "
            f"intensity={t['intensity']:.1f}flop/B "
            f"weight_bytes={t['weight_bytes']:.0f} "
            f"(B={batch} k={k_active} mats={n_mats} d={d_model})"))
    return out


def _advice(dominant: str, cfg: ModelConfig, shape: InputShape) -> str:
    if dominant == "memory":
        if shape.kind == "decode":
            return ("decode is weight/KV-streaming bound: quantise KV or weights, "
                    "or batch more tokens per weight read")
        return "raise arithmetic intensity: fuse, remat less, larger microbatch"
    if dominant == "collective":
        return ("shrink per-layer all-reduces: 2D-shard activations, overlap "
                "collectives with compute, or reduce-scatter+all-gather split")
    if cfg.moe:
        return "compute-bound: cut MoE capacity-factor waste / skip masked blocks"
    return "compute-bound: skip masked flash blocks (causal), near roofline"


def load_dryrun(save_dir: str = "experiments/dryrun") -> Dict[Tuple[str, str, str], dict]:
    out = {}
    for path in glob.glob(os.path.join(save_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        pod = "pod2" if r.get("mesh", {}).get("pod") else "pod1"
        out[(r["arch"], r["shape"], pod)] = r
    return out


def roofline_table(save_dir: str = "experiments/dryrun",
                   archs: Optional[List[str]] = None) -> List[dict]:
    from repro.configs import ASSIGNED_CONFIGS
    from repro.launch.specs import uses_swa_for
    dry = load_dryrun(save_dir)
    rows = []
    for arch in (archs or sorted(ASSIGNED_CONFIGS)):
        cfg = get_config(arch)
        for shape_name, shape in INPUT_SHAPES.items():
            swa = uses_swa_for(cfg, shape)
            t = analytic_terms(cfg, shape, swa)
            terms = {"compute": t["compute_s"], "memory": t["memory_s"],
                     "collective": t["collective_s"]}
            dominant = max(terms, key=terms.get)
            raw = dry.get((arch, shape_name, "pod1"), {})
            rows.append({
                "arch": arch, "shape": shape_name, "swa": swa,
                **{f"{k}_s": v for k, v in terms.items()},
                "dominant": dominant,
                "model_flops": t["model_flops"],
                "hlo_flops_est": t["hlo_flops_est"],
                "useful_ratio": t["useful_ratio"],
                "raw_cost_flops": raw.get("cost_analysis", {}).get("flops"),
                "raw_coll_bytes": raw.get("collective_bytes", {}).get("total"),
                "raw_temp_gib": (raw.get("memory_analysis", {})
                                 .get("temp_size_in_bytes", 0)) / 2**30,
                "advice": _advice(dominant, cfg, shape),
            })
    return rows


def rows_for_run() -> List[Tuple[str, float, str]]:
    out = []
    for r in roofline_table():
        out.append((
            f"roofline/{r['arch']}/{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dominant={r['dominant']} compute={r['compute_s']*1e3:.2f}ms "
            f"memory={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms "
            f"useful={r['useful_ratio']:.2f}"))
    out.extend(sparse_ffn_rows())
    return out
