"""Benchmarks reproducing each paper table/figure (device model = UFS 4.0).

Every function returns rows (name, us_per_call, derived) and corresponds to a
specific artifact of the paper — the mapping is in DESIGN.md §6.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import (BYTES_PER_PARAM, N_SIM_LAYERS, Row,
                               build_sim_model, make_engines, model_geometry,
                               ripple_placements, serve_and_summarise)
from repro.configs.paper_models import PAPER_MODELS
from repro.core import search_placement, stats_from_masks
from repro.core.storage import UFS31, UFS40, UFSDevice

MODELS = ["opt-350m", "opt-1.3b", "opt-6.7b", "llama2-7b-relu", "mistral-7b-relu"]
SHORT = {"opt-350m": "OPT-350M", "opt-1.3b": "OPT-1.3B", "opt-6.7b": "OPT-6.7B",
         "llama2-7b-relu": "Llama2-7B", "mistral-7b-relu": "Mistral-7B"}
PHONE_GFLOPS = 60.0        # Snapdragon 8 Gen 3 effective fp16 GEMV throughput


# -- Fig. 4: bandwidth vs continuous I/O size ---------------------------------

def fig4_bandwidth() -> List[Row]:
    dev = UFSDevice(**UFS40)
    rows = []
    for kb in (4, 8, 16, 24, 32, 64, 128, 256, 512, 1024):
        bw = dev.bandwidth_at_io_size(kb * 1024)
        rows.append((f"fig4/bw_at_{kb}KB", bw / 1e9,
                     f"GB/s; crossover={dev.crossover_bytes()/1e3:.0f}KB"))
    return rows


# -- Table 1: latency breakdown at 50% offload --------------------------------

def table1_breakdown() -> List[Row]:
    rows = []
    for mid in MODELS:
        cfg = PAPER_MODELS[mid]
        n, n_mats, d, sparsity, L = model_geometry(mid)
        params = cfg.param_count()
        compute_ms = 2 * params * 1e3 / (PHONE_GFLOPS * 1e9)
        sim = build_sim_model(mid)
        # 50% offload: FFN lives in flash, activated neurons read per token
        s = serve_and_summarise(sim, "llmflash")
        load_ms = s["io_s_per_token"] * 1e3
        total = compute_ms + load_ms
        rows.append((f"table1/{SHORT[mid]}", total * 1e3,
                     f"compute={compute_ms:.0f}ms load={load_ms:.0f}ms "
                     f"load_ratio={load_ms/total:.1%}"))
    return rows


# -- Fig. 5: latency / bandwidth vs activation sparsity ------------------------

def fig5_sparsity_latency() -> List[Row]:
    from repro.core.trace import SyntheticTraceConfig, synthetic_masks
    from repro.core import OffloadEngine, EngineConfig, identity_placement
    n, n_mats, d, _, L = model_geometry("opt-350m")
    dev = UFSDevice(**UFS40)
    rows = []
    dense_bytes = n * n_mats * d * BYTES_PER_PARAM
    dense_time = dev.read_time(1, dense_bytes)
    for ratio in (0.05, 0.1, 0.2, 0.4, 0.8, 1.0):
        cfg = SyntheticTraceConfig(n_neurons=n, n_clusters=64,
                                   clusters_per_token=min(64, max(1, int(ratio * 64 / 0.9))),
                                   member_p=min(0.95, ratio / (min(64, max(1, int(ratio * 64 / 0.9))) / 64)),
                                   noise_p=0.0, seed=5)
        masks = synthetic_masks(cfg, 60)
        eng = OffloadEngine(np.zeros((n, n_mats * d), np.float16),
                            placement=identity_placement(n), device=dev,
                            config=EngineConfig(cache_ratio=0.0, collapse=False,
                                                linking_aligned_cache=False))
        eng.run_trace(masks)
        s = eng.summary()
        t = s["io_seconds_per_token"] * L
        rows.append((f"fig5/sparsity_{ratio:.2f}", t * 1e6,
                     f"io_us/token scattered; dense_contig={dense_time*L*1e6:.0f}us "
                     f"bw={s['effective_bandwidth']/1e9:.2f}GB/s"))
    return rows


# -- Fig. 10: overall latency + bandwidth vs baselines -------------------------

def fig10_overall() -> List[Row]:
    rows = []
    for mid in MODELS:
        sim = build_sim_model(mid)
        res = {sys: serve_and_summarise(sim, sys)
               for sys in ("llama.cpp", "llmflash", "ripple")}
        r = res["ripple"]
        rows.append((
            f"fig10/{SHORT[mid]}/io_latency", r["io_s_per_token"] * 1e6,
            f"us/token; speedup_vs_llama.cpp={res['llama.cpp']['io_s_per_token']/r['io_s_per_token']:.2f}x "
            f"speedup_vs_llmflash={res['llmflash']['io_s_per_token']/r['io_s_per_token']:.2f}x"))
        rows.append((
            f"fig10/{SHORT[mid]}/bandwidth", r["effective_bandwidth"] / 1e9,
            f"GB/s; gain_vs_llama.cpp={r['effective_bandwidth']/max(res['llama.cpp']['effective_bandwidth'],1):.2f}x "
            f"gain_vs_llmflash={r['effective_bandwidth']/max(res['llmflash']['effective_bandwidth'],1):.2f}x"))
    return rows


# -- Fig. 11: offline / online stage breakdown ---------------------------------

def fig11_breakdown() -> List[Row]:
    rows = []
    for mid in MODELS:
        sim = build_sim_model(mid)
        base = serve_and_summarise(sim, "llmflash")["io_s_per_token"]
        off = serve_and_summarise(sim, "ripple-offline")["io_s_per_token"]
        on = serve_and_summarise(sim, "ripple-online")["io_s_per_token"]
        both = serve_and_summarise(sim, "ripple")["io_s_per_token"]
        rows.append((f"fig11/{SHORT[mid]}", both * 1e6,
                     f"us/token; offline={base/off:.2f}x online={base/on:.2f}x "
                     f"combined={base/both:.2f}x"))
    return rows


# -- Fig. 12: continuous access length -----------------------------------------

def fig12_access_length() -> List[Row]:
    rows = []
    for mid in ("opt-6.7b", "llama2-7b-relu"):
        sim = build_sim_model(mid)
        flash = serve_and_summarise(sim, "llmflash")
        ripple = serve_and_summarise(sim, "ripple")
        rows.append((f"fig12/{SHORT[mid]}", ripple["mean_run_length"],
                     f"mean_run_ripple vs {flash['mean_run_length']:.2f} llmflash "
                     f"(+{(ripple['mean_run_length']/flash['mean_run_length']-1)*100:.0f}%); "
                     f"max_run={ripple['max_run_length']}"))
    return rows


# -- Table 4: offline search cost ----------------------------------------------

def table4_search_time() -> List[Row]:
    rows = []
    for mid in MODELS:
        sim = build_sim_model(mid)
        t0 = time.perf_counter()
        stats = stats_from_masks(sim.calib[0])
        res = search_placement(stats.distance_matrix(), mode="auto")
        per_layer = time.perf_counter() - t0
        total = per_layer * sim.n_layers_real   # paper parallelises across layers
        rows.append((f"table4/{SHORT[mid]}", per_layer * 1e6,
                     f"us/layer mode={res.mode}; serial_total={total:.1f}s "
                     f"n={sim.n_neurons}"))
    return rows


# -- Fig. 13: access collapse ablation -------------------------------------------

def fig13_collapse() -> List[Row]:
    rows = []
    for mid in ("opt-6.7b", "llama2-7b-relu"):
        sim = build_sim_model(mid)
        off = serve_and_summarise(sim, "ripple-offline")      # placement, no collapse
        full = serve_and_summarise(sim, "ripple")             # + collapse + cache
        rows.append((f"fig13/{SHORT[mid]}", full["effective_bandwidth"] / 1e9,
                     f"GB/s; bw_gain={full['effective_bandwidth']/off['effective_bandwidth']:.2f}x "
                     f"iops {off['ops_per_token']:.0f}->{full['ops_per_token']:.0f}/tok "
                     f"extra_bytes={full['waste_ratio']:.1%}"))
    return rows


# -- Fig. 14: DRAM cache ratio ---------------------------------------------------

def fig14_cache_ratio() -> List[Row]:
    rows = []
    mid = "opt-6.7b"
    sim = build_sim_model(mid)
    flash_curve = {r: serve_and_summarise(sim, "llmflash", cache_ratio=r)["io_s_per_token"]
                   for r in (0.0, 0.05, 0.1, 0.2, 0.4)}
    ripple_curve = {r: serve_and_summarise(sim, "ripple", cache_ratio=r)["io_s_per_token"]
                    for r in (0.0, 0.05, 0.1, 0.2, 0.4)}
    # memory savings: smallest ripple ratio at least as fast as llmflash@0.4
    target = flash_curve[0.4]
    saving_ratio = next((r for r in (0.0, 0.05, 0.1, 0.2, 0.4)
                         if ripple_curve[r] <= target), 0.4)
    for r in (0.0, 0.05, 0.1, 0.2, 0.4):
        rows.append((f"fig14/{SHORT[mid]}/ratio_{r:.2f}", ripple_curve[r] * 1e6,
                     f"us/token ripple vs {flash_curve[r]*1e6:.0f}us llmflash"))
    rows.append((f"fig14/{SHORT[mid]}/mem_saving", 0.4 / max(saving_ratio, 0.05),
                 f"x cache-space saving (ripple@{saving_ratio} <= llmflash@0.4)"))
    return rows


# -- Fig. 15: input-dataset sensitivity -------------------------------------------

def fig15_sensitivity() -> List[Row]:
    """Placement extracted with dataset A, served with dataset B (zipf shift).

    Cluster membership (model-intrinsic) is held fixed per layer; cluster
    popularity (dataset-dependent) changes with the zipf exponent.
    """
    rows = []
    mid = "opt-1.3b"
    datasets = {"alpaca": (1.1, 11), "openwebtext": (0.7, 22), "wikitext": (1.5, 33)}
    for calib_name, (calib_z, calib_p) in datasets.items():
        for serve_name, (serve_z, serve_p) in datasets.items():
            sim = build_sim_model(mid, zipf=calib_z, serve_zipf=serve_z,
                                  calib_pop=calib_p, serve_pop=serve_p)
            r = serve_and_summarise(sim, "ripple")
            b = serve_and_summarise(sim, "llmflash")
            rows.append((f"fig15/{calib_name}->{serve_name}",
                         r["io_s_per_token"] * 1e6,
                         f"us/token; speedup={b['io_s_per_token']/r['io_s_per_token']:.2f}x"))
    return rows


# -- Fig. 16: hardware sensitivity -------------------------------------------------

def fig16_hardware() -> List[Row]:
    rows = []
    devices = {"OP12_UFS4.0": UFSDevice(**UFS40), "OPAce2_UFS3.1": UFSDevice(**UFS31)}
    for mid in ("opt-6.7b",):
        for name, dev in devices.items():
            sim = build_sim_model(mid)
            r = serve_and_summarise(sim, "ripple", device=dev)
            rows.append((f"fig16/{SHORT[mid]}/{name}", r["io_s_per_token"] * 1e6,
                         f"us/token bw={r['effective_bandwidth']/1e9:.2f}GB/s"))
    return rows


# -- Fig. 17: precision sensitivity -------------------------------------------------

def fig17_precision() -> List[Row]:
    """Lower precision -> smaller bundles -> more IOPS-bound; RIPPLE holds up."""
    rows = []
    mid = "opt-6.7b"
    n, n_mats, d, _, L = model_geometry(mid)
    for bits, name in ((16, "fp16"), (8, "int8"), (4, "int4")):
        sim = build_sim_model(mid)
        # shrink bundle width to model precision
        sim_scaled = type(sim)(
            model_id=sim.model_id, calib=sim.calib, serve=sim.serve,
            bundles=np.zeros((n, max(1, n_mats * d * bits // 16)), np.float16),
            n_mats=sim.n_mats, n_layers_real=sim.n_layers_real)
        r = serve_and_summarise(sim_scaled, "ripple")
        b = serve_and_summarise(sim_scaled, "llmflash")
        rows.append((f"fig17/{name}", r["io_s_per_token"] * 1e6,
                     f"us/token; speedup_vs_llmflash={b['io_s_per_token']/r['io_s_per_token']:.2f}x"))
    return rows
