"""Beyond-paper benchmark: RIPPLE at EXPERT granularity for the assigned MoE
architectures (granite-moe 32e/40e top-8, jamba 16e top-2).

Each expert is a large contiguous flash object (3·d·d_ff_expert params); a
token's read set is its top-k experts. Expert co-routing plays the role of
neuron co-activation; placement + collapse reduce per-token expert reads.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.configs import get_config
from repro.core import (EngineConfig, OffloadEngine, expected_reads_per_token,
                        identity_placement, search_expert_placement,
                        synthetic_routing)
from repro.core.expert_placement import routing_masks
from repro.core.storage import UFS40, UFSDevice

Row = Tuple[str, float, str]

MOE_ARCHS = ["granite-moe-1b-a400m", "granite-moe-3b-a800m", "jamba-1.5-large-398b"]


def moe_expert_bench() -> List[Row]:
    rows: List[Row] = []
    dev = UFSDevice(**UFS40)
    for arch in MOE_ARCHS:
        cfg = get_config(arch)
        E, k = cfg.moe.n_experts, cfg.moe.top_k
        d_ff = cfg.moe.d_ff_expert
        expert_bytes = 3 * cfg.d_model * d_ff * 2      # bf16 bundle per expert
        calib = synthetic_routing(1200, E, k, n_groups=max(2, E // 8), seed=11)
        serve = synthetic_routing(400, E, k, n_groups=max(2, E // 8), seed=99)
        pl = search_expert_placement(calib, E)
        ident = identity_placement(E)
        r_i = expected_reads_per_token(serve, E, ident)
        r_p = expected_reads_per_token(serve, E, pl)
        # per-token I/O through the engine (expert bundles; no cache — experts
        # are large, DRAM holds at most a couple). Payload array is a tiny
        # stand-in; I/O accounting uses the true expert_bytes.
        bundles = np.zeros((E, 8), np.float32)
        results = {}
        for name, placement in (("identity", ident), ("ripple", pl)):
            eng = OffloadEngine(bundles, placement=placement, device=dev,
                                config=EngineConfig(cache_ratio=0.0),
                                bundle_bytes=expert_bytes)
            eng.run_trace(routing_masks(serve, E))
            results[name] = eng.summary()
        t_i = results["identity"]["io_seconds_per_token"]
        t_p = results["ripple"]["io_seconds_per_token"]
        rows.append((
            f"moe_expert/{arch}", t_p * 1e6,
            f"us/token/layer; reads {r_i:.2f}->{r_p:.2f} "
            f"io_speedup={t_i/t_p:.2f}x expert={expert_bytes/2**20:.1f}MiB"))
    return rows
