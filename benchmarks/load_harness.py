"""SLO load harness: mlperf-style open-loop arrival sweeps against the
InferenceServer, resident and offload, with tail-latency + overload gates.

The claim under test (ISSUE 8 acceptance): the serving layer is
overload-ROBUST — under sustained arrivals past capacity the server sheds
and times out work by policy (bounded queue, priority + EDF admission,
monotonic deadlines) instead of collapsing, while every request it does
serve stays token-identical to an unloaded run.

Per mode (resident | offload), the harness:

  1. runs an UNLOADED reference (submit-all + drain, no SLOs) — warms every
     jit shape and records each uid's ground-truth tokens;
  2. CALIBRATES the sustainable rate: a closed-loop drain gives
     requests-per-second at full occupancy and the mean decode-step wall,
     from which the SLO knobs derive (itl_slo = ITL_SLO_STEPS x mean step,
     ttft_slo = TTFT_QUEUE_FRACTION of a full queue's drain time) — so the
     same harness is meaningful on any machine speed;
  3. drives OPEN-LOOP arms at 0.5x ("under"), 1.0x ("at"), 2.0x ("over")
     the sustainable rate plus a bursty at-capacity arm (Poisson bursts of
     BURST_SIZE), submitting on a real monotonic clock and recording
     p50/p95/p99 TTFT + inter-token latency, queue depth, and the
     shed/reject/timeout counters. The overload arm alternates priority
     classes so both shedding (priority preemption of queued work) and
     TTFT timeouts actually engage.

Tail latency is gated MACHINE-NORMALIZED: p99 inter-token latency in units
of the same run's calibrated mean decode step (`p99_itl_steps`), compared
against the committed BENCH_slo.json within `--itl-tolerance`.

A fourth arm (`paged_pressure`) drives the paged-KV server against a pool
far smaller than the admitted requests' worst case (overcommit admission):
page-availability deferrals and policy preemption must both engage, every
completed request must stay token-identical to an unloaded contiguous run
(preempted partials exact prefixes), and the page allocator must conserve
every page across all retirement paths (free list full after drain +
registry clear, allocated == freed).

Writes ``BENCH_slo.json``::

  {"meta": {...geometry, counts, slo derivation...},
   "modes": {"resident": {"calibration": {...}, "arms": {"under": {...},
             "at": {...}, "over": {...}, "burst": {...}}},
             "offload": {...}},
   "paged_pressure": {...counters, identity + conservation audits...},
   "gates": {"under_capacity_clean", "overload_bounded_queue",
             "overload_sheds", "overload_timeouts", "counters_conserved",
             "io_attribution_conserved", "tokens_identical",
             "p99_itl_within_tolerance", "paged_pressure_engages",
             "paged_counters_conserved", "paged_tokens_prefix_identical",
             "paged_pages_conserved"}}

Gates (``--check``, run in CI): every entry of `gates` must be true —
(a) zero sheds/rejects/timeouts/errors at the under-capacity rate,
(b) the 2x-overload arm completes with queue depth bounded by queue_limit
    and nonzero shed AND timeout counters,
(c) per-arm conservation: every submitted request retires with exactly one
    finish_reason, and in offload mode the per-request io_seconds sum
    equals the engines' merged read seconds (timed-out rows included),
(d) every served token sequence is a prefix (complete for finish_reason
    "length"/"stop") of the unloaded reference for that uid,
(e) p99_itl_steps within tolerance of the committed baseline.

Run: PYTHONPATH=src python benchmarks/load_harness.py [--quick] [--check]
         [--out F] [--itl-tolerance X]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

if __package__ in (None, ""):                     # standalone script mode
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config
from repro.core import EngineConfig
from repro.models import build_model
from repro.obs import enable_tracing
from repro.serving.engine import Request, build_offload_runtime
from repro.serving.server import InferenceServer
from repro.utils import add_verbosity_flag, configure_logging, get_logger

log = get_logger("bench.load")

MODES = ("resident", "offload")
MAX_SLOTS = 4
PROMPT_LEN = 12
NEW_TOKENS = 8
# itl_slo = 300 x calibrated mean decode step + a slot pool's worth of
# admission prefills: two orders of magnitude above steady-state gaps, so
# only genuine stalls trip it (CI-runner hiccup proof)
ITL_SLO_STEPS = 300.0
ITL_SLO_PREFILLS = float(MAX_SLOTS)
# queue_limit ~ QUEUE_SECONDS of sustainable service (capped at n/6 so the
# overload arm genuinely fills it), ttft_slo = 0.75 x the full-queue drain
# time — structurally BELOW the queue wait at saturation on any machine, so
# 2x overload always produces TTFT timeouts, and structurally ABOVE any
# under-capacity wait, which keeps the under arm clean
QUEUE_SECONDS = 0.75
TTFT_QUEUE_FRACTION = 0.75
BURST_SIZE = 8
RATE_ARMS = (("under", 0.5, 1), ("at", 1.0, 1), ("over", 2.0, 1),
             ("burst", 1.0, BURST_SIZE))


def _workload(quick: bool) -> dict:
    # geometry is IDENTICAL in quick and full runs — only request counts
    # shrink — so the machine-normalized tail metric (p99 in units of mean
    # decode step) is comparable between the committed full run and CI smoke
    cfg = get_config("opt-350m", reduced=True, d_model=48, d_ff=192,
                     n_layers=2, vocab_size=128, activation="relu")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n_req = {"resident": 300 if quick else 1000,
             "offload": 80 if quick else 200}
    n_cal = 16 if quick else 32
    rng = np.random.default_rng(7)
    n_pool = max(n_req.values())
    pool = [Request(uid=i,
                    prompt=rng.integers(0, 128, PROMPT_LEN).astype(np.int32),
                    max_new_tokens=NEW_TOKENS) for i in range(n_pool)]
    return dict(cfg=cfg, model=model, params=params, pool=pool, n_req=n_req,
                n_cal=n_cal,
                meta=dict(quick=quick, d_model=48, d_ff=192, n_layers=2,
                          vocab=128, max_slots=MAX_SLOTS,
                          prompt_len=PROMPT_LEN, new_tokens=NEW_TOKENS,
                          n_req=n_req, n_cal=n_cal,
                          itl_slo_steps=ITL_SLO_STEPS,
                          queue_seconds=QUEUE_SECONDS,
                          ttft_queue_fraction=TTFT_QUEUE_FRACTION,
                          burst_size=BURST_SIZE))


def _make_server(w: dict, mode: str, runtime, fns, **kw):
    decode_fn, prefill_fn = fns
    return InferenceServer(w["model"], w["params"], max_slots=MAX_SLOTS,
                           max_len=PROMPT_LEN + NEW_TOKENS + 4, mode=mode,
                           offload=runtime if mode == "offload" else None,
                           decode_fn=decode_fn if mode == "resident" else None,
                           prefill_fn=prefill_fn, seed=0, **kw)


def _engine_io_seconds(runtime) -> float:
    return sum(t.io.seconds for e in runtime.engines for t in e.history)


def _reference(w: dict, mode: str, runtime, fns) -> dict:
    """Unloaded ground truth: this mode's pool prefix decoded with no SLOs,
    no queue bound, submit-all + drain. Grouping-invariant sampling makes
    this THE reference for every loaded arm, whatever batch each request
    lands in. Runs first, so it also warms every jit shape."""
    server = _make_server(w, mode, runtime, fns)
    try:
        handles = [server.submit(r)
                   for r in w["pool"][:w["n_req"][mode]]]
        server.drain()
        return {h.uid: list(h.tokens) for h in handles}
    finally:
        server.close()


def _calibrate(w: dict, mode: str, runtime, fns) -> dict:
    """Closed-loop drain at full occupancy -> sustainable request rate, mean
    decode-step wall, and mean admission-prefill wall. Every SLO knob
    derives from these, so the harness is meaningful at any machine speed:
    the queue holds ~QUEUE_SECONDS of service (capped so the overload arm
    fills it), the TTFT deadline sits at 75%% of a full queue's drain time
    (under saturation the queue wait EXCEEDS it, under capacity nothing
    comes near it), and the inter-token deadline sits two orders of
    magnitude above a steady-state gap."""
    reqs = w["pool"][:w["n_cal"]]
    server = _make_server(w, mode, runtime, fns)
    try:
        t0 = time.monotonic()
        for r in reqs:
            server.submit(r)
        server.drain()
        wall = time.monotonic() - t0
        st = server.stats
        mean_step = st.decode_seconds / max(st.decode_steps, 1)
        mean_prefill = st.prefill_seconds / max(st.admitted, 1)
    finally:
        server.close()
    sustainable = len(reqs) / wall
    n = w["n_req"][mode]
    queue_limit = int(min(max(8, round(QUEUE_SECONDS * sustainable)), n // 6))
    itl_slo = ITL_SLO_STEPS * mean_step + ITL_SLO_PREFILLS * mean_prefill
    ttft_slo = TTFT_QUEUE_FRACTION * queue_limit / sustainable
    return dict(sustainable_req_s=round(sustainable, 2),
                mean_step_s=mean_step,
                mean_step_ms=round(mean_step * 1e3, 4),
                mean_prefill_ms=round(mean_prefill * 1e3, 4),
                itl_slo_ms=round(itl_slo * 1e3, 2),
                ttft_slo_ms=round(ttft_slo * 1e3, 2),
                queue_limit=queue_limit,
                _itl_slo=itl_slo, _ttft_slo=ttft_slo)


def _arrivals(n: int, rate: float, burst: int, seed: int) -> np.ndarray:
    """Open-loop arrival offsets: Poisson bursts of `burst` requests sharing
    one instant, inter-burst gaps ~ Exp(burst/rate) so the mean rate is
    `rate` regardless of burst size."""
    rng = np.random.default_rng(seed)
    n_bursts = -(-n // burst)
    burst_times = np.cumsum(rng.exponential(burst / rate, n_bursts))
    return np.repeat(burst_times, burst)[:n]


def _pct(xs, q) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def _arm(w: dict, mode: str, runtime, fns, cal: dict, ref: dict,
         name: str, rate_x: float, burst: int, seed: int) -> dict:
    """One open-loop arm: submit on the real monotonic clock at
    rate_x x sustainable, step whenever there is work, then audit."""
    n = w["n_req"][mode]
    rate = rate_x * cal["sustainable_req_s"]
    arrivals = _arrivals(n, rate, burst, seed)
    # the overload arm mixes priority classes so queue-full arrivals SHED
    # lower-priority queued work (not just reject newcomers)
    reqs = [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens,
                    priority=(r.uid % 2 if rate_x > 1.0 else 0))
            for r in w["pool"][:n]]
    if runtime is not None:
        runtime.reset_stats()
    server = _make_server(w, mode, runtime, fns,
                          queue_limit=cal["queue_limit"],
                          ttft_slo_s=cal["_ttft_slo"],
                          itl_slo_s=cal["_itl_slo"],
                          finished_high_water=2 * cal["queue_limit"])
    handles, depths = [], []
    t0 = time.monotonic()
    try:
        i = 0
        while i < n or server.has_work:
            now = time.monotonic() - t0
            while i < n and arrivals[i] <= now:
                handles.append(server.submit(reqs[i]))
                i += 1
            if server.has_work:
                server.step()
                depths.append(server.n_queued)
            elif i < n:
                time.sleep(min(arrivals[i] - now, 0.002))
        wall = time.monotonic() - t0
    finally:
        server.close()

    reasons = {"length": 0, "stop": 0, "timeout": 0, "rejected": 0, "error": 0}
    ttfts, gaps = [], []
    identical = True
    for h in handles:
        reasons[h.finish_reason] = reasons.get(h.finish_reason, 0) + 1
        if h.first_token_at is not None:
            ttfts.append(h.first_token_at - h.queued_at)
        if len(h.token_times) >= 2:
            gaps.extend(np.diff(h.token_times).tolist())
        # token identity vs the unloaded reference: complete requests must
        # match exactly, timed-out partials must be a prefix
        expect = ref[h.uid]
        if h.finish_reason in ("length", "stop"):
            identical &= h.tokens == expect
        elif h.finish_reason == "timeout":
            identical &= h.tokens == expect[:len(h.tokens)]
    st = server.stats
    conserved = (len(handles) == n and all(h.done for h in handles)
                 and sum(reasons.values()) == n
                 and reasons["timeout"] == st.timeouts
                 and reasons["rejected"] == st.rejected + st.shed)
    out = dict(
        offered_req_s=round(rate, 2), burst=burst, n=n, wall_s=round(wall, 2),
        **reasons,
        shed=st.shed, hard_rejected=st.rejected,
        io_deferrals=st.io_deferrals,
        results_auto_released=st.results_released,
        peak_queue_depth=st.peak_queue_depth,
        mean_queue_depth=round(float(np.mean(depths)) if depths else 0.0, 2),
        tokens_per_s=round(st.tokens_emitted / max(wall, 1e-9), 1),
        p50_ttft_ms=round(_pct(ttfts, 50) * 1e3, 2),
        p95_ttft_ms=round(_pct(ttfts, 95) * 1e3, 2),
        p99_ttft_ms=round(_pct(ttfts, 99) * 1e3, 2),
        p50_itl_ms=round(_pct(gaps, 50) * 1e3, 3),
        p95_itl_ms=round(_pct(gaps, 95) * 1e3, 3),
        p99_itl_ms=round(_pct(gaps, 99) * 1e3, 3),
        # machine-normalized tail metric: p99 ITL in units of this run's
        # calibrated mean decode step (what the committed-baseline gate uses)
        p99_itl_steps=round(_pct(gaps, 99) / cal["mean_step_s"], 2),
        counters_conserved=bool(conserved),
        tokens_identical=bool(identical),
    )
    if runtime is not None:
        attributed = sum(h.io_seconds for h in handles)
        engine = _engine_io_seconds(runtime)
        out["io_attributed_s"] = round(attributed, 6)
        out["io_engine_s"] = round(engine, 6)
        out["io_conserved"] = bool(abs(attributed - engine)
                                   <= 1e-6 + 1e-6 * max(engine, 1.0))
    return out


PAGED_PAGE_SIZE = 4
PAGED_NUM_PAGES = 12        # 48 KV positions: MAX_SLOTS x prompt pages fill
                            # the pool at admission, so every decode-time
                            # growth runs the arena dry (overcommit pressure)


def _paged_pressure(w: dict, fns) -> dict:
    """KV-memory-bounded arm: the paged server under genuine page pressure.

    The pool is sized so MAX_SLOTS admitted prompts fill it exactly
    (overcommit admits on prompt pages, not the committed worst case), which
    forces the decode-time growth path dry on every request: admissions
    defer on page availability, and when no page can be found the server
    preempts by policy. The audits mirror the SLO arms: every submission
    retires exactly once, completed requests are token-identical to an
    unloaded contiguous run (grouping-invariant sampling + the paged
    kernel's bitwise identity make it the ground truth), preempted partial
    outputs are exact prefixes, and after drain + registry clear the
    allocator conserves every page (free list full, allocated == freed)."""
    from repro.serving.server import InferenceServer as _IS

    n = 24 if w["meta"]["quick"] else 60
    reqs = w["pool"][:n]
    server = _make_server(w, "resident", None, fns)
    try:
        handles = [server.submit(r) for r in reqs]
        server.drain()
        ref = {h.uid: list(h.tokens) for h in handles}
    finally:
        server.close()

    server = _IS(w["model"], w["params"], max_slots=MAX_SLOTS,
                 max_len=PROMPT_LEN + NEW_TOKENS + 4, prefill_fn=fns[1],
                 seed=0, page_size=PAGED_PAGE_SIZE,
                 num_pages=PAGED_NUM_PAGES, page_overcommit=True)
    t0 = time.monotonic()
    try:
        handles = [server.submit(r) for r in reqs]
        server.drain()
        wall = time.monotonic() - t0
        psum = server.page_summary()
        pool = server._pool
        pool.clear_prefix_cache()
        audit_clean = True
        try:
            pool.check()
        except AssertionError:
            audit_clean = False
        pages_conserved = (audit_clean and pool.n_free == PAGED_NUM_PAGES
                           and pool.stats.pages_allocated
                           == pool.stats.pages_freed)
    finally:
        server.close()

    reasons: dict = {}
    identical = True
    for h in handles:
        reasons[h.finish_reason] = reasons.get(h.finish_reason, 0) + 1
        expect = ref[h.uid]
        if h.finish_reason in ("length", "stop"):
            identical &= h.tokens == expect
        else:                      # preempted/timeout partials: exact prefix
            identical &= h.tokens == expect[:len(h.tokens)]
    st = server.stats
    conserved = (len(handles) == n and all(h.done for h in handles)
                 and sum(reasons.values()) == n
                 and reasons.get("preempted", 0) == st.preemptions)
    return dict(
        n=n, wall_s=round(wall, 2),
        page_size=PAGED_PAGE_SIZE, num_pages=PAGED_NUM_PAGES,
        kv_positions=PAGED_PAGE_SIZE * PAGED_NUM_PAGES,
        reasons=reasons,
        preemptions=psum["preemptions"],
        page_deferrals=psum["page_deferrals"],
        cow_copies=psum["cow_copies"],
        peak_page_occupancy=psum["peak_page_occupancy"],
        pages_allocated=psum["pages_allocated"],
        pages_freed_total=pool.stats.pages_freed,
        tokens_per_s=round(st.tokens_emitted / max(wall, 1e-9), 1),
        counters_conserved=bool(conserved),
        tokens_prefix_identical=bool(identical),
        pages_conserved=bool(pages_conserved),
    )


def run(quick: bool, itl_tolerance: float = 3.0,
        committed: dict | None = None) -> dict:
    w = _workload(quick)
    report = {"meta": dict(w["meta"], itl_tolerance=itl_tolerance),
              "modes": {}}
    fns = (jax.jit(lambda p, t, pos, c: w["model"].decode_step(p, t, pos, c)),
           jax.jit(lambda p, toks, c: w["model"].prefill(
               p, {"tokens": toks}, c)))
    runtime = build_offload_runtime(w["model"], w["params"],
                                    rng=np.random.default_rng(0),
                                    engine_cfg=EngineConfig())
    try:
        for mode in MODES:
            rt = runtime if mode == "offload" else None
            ref = _reference(w, mode, rt, fns)
            cal = _calibrate(w, mode, rt, fns)
            arms = {}
            for i, (name, rate_x, burst) in enumerate(RATE_ARMS):
                arms[name] = _arm(w, mode, rt, fns, cal, ref,
                                  name, rate_x, burst, seed=100 + i)
            report["modes"][mode] = {
                "calibration": {k: v for k, v in cal.items()
                                if not k.startswith("_")},
                "arms": arms}
        report["paged_pressure"] = _paged_pressure(w, fns)
    finally:
        runtime.close()

    def every(pred):
        return all(pred(m, a, arm) for m, md in report["modes"].items()
                   for a, arm in md["arms"].items())

    under = {m: md["arms"]["under"] for m, md in report["modes"].items()}
    over = {m: md["arms"]["over"] for m, md in report["modes"].items()}
    tail_ok, tail_detail = True, {}
    if committed:
        for m in MODES:
            try:
                base = committed["modes"][m]["arms"]["under"]["p99_itl_steps"]
            except (KeyError, TypeError):
                continue
            fresh = under[m]["p99_itl_steps"]
            ok = base <= 0 or fresh <= itl_tolerance * base
            tail_ok &= ok
            tail_detail[m] = dict(committed=base, fresh=fresh, ok=ok)
    report["tail_vs_committed"] = tail_detail or None
    report["gates"] = {
        "under_capacity_clean": all(
            a["rejected"] + a["shed"] + a["timeout"] + a["error"] == 0
            for a in under.values()),
        "overload_bounded_queue": all(
            md["arms"]["over"]["peak_queue_depth"]
            <= md["calibration"]["queue_limit"]
            and md["arms"]["over"]["counters_conserved"]
            for md in report["modes"].values()),
        "overload_sheds": all(a["shed"] + a["hard_rejected"] > 0
                              for a in over.values()),
        "overload_timeouts": all(a["timeout"] > 0 for a in over.values()),
        "counters_conserved": every(lambda m, a, arm: arm["counters_conserved"]),
        "io_attribution_conserved": all(
            arm["io_conserved"]
            for arm in report["modes"]["offload"]["arms"].values()),
        "tokens_identical": every(lambda m, a, arm: arm["tokens_identical"]),
        "p99_itl_within_tolerance": bool(tail_ok),
        "paged_pressure_engages": (
            report["paged_pressure"]["preemptions"] > 0
            and report["paged_pressure"]["page_deferrals"] > 0),
        "paged_counters_conserved":
            report["paged_pressure"]["counters_conserved"],
        "paged_tokens_prefix_identical":
            report["paged_pressure"]["tokens_prefix_identical"],
        "paged_pages_conserved": report["paged_pressure"]["pages_conserved"],
    }
    return report


def load_harness():
    """benchmarks/run.py suite entry: (name, us_per_call, derived) rows."""
    r = run(quick=True)
    rows = []
    for mode, md in r["modes"].items():
        cal = md["calibration"]
        rows.append((f"load_harness/{mode}_sustainable_req_s",
                     cal["sustainable_req_s"],
                     f"mean step {cal['mean_step_ms']}ms, itl_slo "
                     f"{cal['itl_slo_ms']}ms, ttft_slo {cal['ttft_slo_ms']}ms"))
        for name, a in md["arms"].items():
            rows.append((
                f"load_harness/{mode}_{name}_p99_itl_ms", a["p99_itl_ms"],
                f"{a['offered_req_s']}req/s burst={a['burst']}: "
                f"{a['length'] + a['stop']} ok, {a['rejected']} rejected "
                f"({a['shed']} shed), {a['timeout']} timeout, peak queue "
                f"{a['peak_queue_depth']}, identical={a['tokens_identical']}"))
    pp = r["paged_pressure"]
    rows.append((
        "load_harness/paged_pressure_tokens_per_s", pp["tokens_per_s"],
        f"{pp['num_pages']}x{pp['page_size']}-token pool (overcommit): "
        f"{pp['preemptions']} preempted, {pp['page_deferrals']} page "
        f"deferrals, identical={pp['tokens_prefix_identical']}, "
        f"pages conserved={pp['pages_conserved']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced request counts for the CI smoke run "
                         "(model geometry unchanged, so machine-normalized "
                         "tail metrics stay comparable to the committed run)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every gate holds: clean "
                         "under-capacity arms, bounded queue + engaged "
                         "backpressure at 2x overload, counter + io_seconds "
                         "conservation, token identity vs the unloaded "
                         "reference, and p99 inter-token latency (in mean "
                         "decode steps) within tolerance of the committed "
                         "baseline")
    ap.add_argument("--itl-tolerance", type=float, default=3.0,
                    help="allowed ratio of fresh p99_itl_steps to the "
                         "committed value (machine-normalized)")
    ap.add_argument("--out", default="BENCH_slo.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a Perfetto timeline of the whole sweep and "
                         "write it to PATH (open at https://ui.perfetto.dev)")
    add_verbosity_flag(ap)
    args = ap.parse_args()
    configure_logging(args.verbose)
    tracer = enable_tracing() if args.trace_out else None

    out = pathlib.Path(args.out)
    committed = None
    if out.exists():        # read the baseline BEFORE overwriting it
        try:
            committed = json.loads(out.read_text())
        except (json.JSONDecodeError, OSError):
            committed = None

    report = run(args.quick, itl_tolerance=args.itl_tolerance,
                 committed=committed)
    out.write_text(json.dumps(report, indent=2) + "\n")
    if tracer is not None:
        events = tracer.export(args.trace_out)
        log.info("trace: %d events (%d dropped) -> %s; open it at "
                 "https://ui.perfetto.dev", len(events), tracer.dropped,
                 args.trace_out)
    print(json.dumps(report, indent=2))     # machine-parseable surface
    if args.check:
        bad = [k for k, ok in report["gates"].items() if not ok]
        if bad:
            sys.exit(f"SLO load gates failed: {', '.join(bad)}")
        log.info("SLO load gates OK: %s", ", ".join(report["gates"]))


if __name__ == "__main__":
    main()
