"""Pack I/O benchmark: linked vs identity NeuronPack layout on the ACTUAL
filesystem.

The paper's claim, finally on a storage medium: write the same neuron bundles
to disk twice — once in co-activation-linked physical order, once in model
(identity) order — and serve the same activation trace through
`FileNeuronStore` + `OffloadEngine` from each. Every collapsed extent is one
real positional `pread`, so the linked layout's longer runs show up as FEWER
real file reads (the deterministic gate) and less real wall time (reported,
never gated — see the caveat below).

Writes ``BENCH_pack.json``::

  {"meta": {...workload geometry, pack sizes/build times...},
   "identity": {"extents", "modeled_io_ms_per_token", "measured_io_ms_per_token",
                "measured_mb_read", "mean_run_length"},
   "linked":   {...},
   "extent_ratio": identity.extents / linked.extents,
   "measured_speedup": ...,
   "modeled_identity_checked": true,
   "caveat": "..."}

Gate (``--check``, run in CI): linked-layout extent count <= identity-layout
extent count on the real file path. Extent counts are deterministic
(placement + trace + cache decisions), unlike wall time.

CAVEAT on measured numbers: in a CI container the page cache warms after the
first pass over the pack, so measured_seconds reflect cached-read syscall
cost, not cold-flash latency — that is exactly why the calibrated UFSDevice
model remains the quantitative latency source (dual accounting), while the
measured fields prove the reads are real and count them.

Run: PYTHONPATH=src python benchmarks/pack_io.py [--quick] [--check] [--out F]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import time

import numpy as np

if __package__ in (None, ""):                     # standalone script mode
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.core.coactivation import stats_from_masks
from repro.core.engine import EngineConfig, OffloadEngine
from repro.core.placement import identity_placement, search_placement
from repro.core.trace import SyntheticTraceConfig, synthetic_masks
from repro.store import FileNeuronStore, write_pack
from repro.utils import add_verbosity_flag, configure_logging, get_logger

log = get_logger("bench.pack")


def _workload(quick: bool):
    n_neurons = 2048 if quick else 4096
    width = 64
    calib = 160 if quick else 384
    serve = 96 if quick else 256
    tc = SyntheticTraceConfig(n_neurons=n_neurons, n_clusters=48,
                              clusters_per_token=5, member_p=0.9,
                              noise_p=0.005, seed=0, structure_seed=0)
    masks = synthetic_masks(tc, calib + serve)
    rng = np.random.default_rng(1)
    bundles = rng.standard_normal((n_neurons, width)).astype(np.float32)
    return dict(n_neurons=n_neurons, width=width, bundles=bundles,
                calib_masks=masks[:calib], serve_masks=masks[calib:])


def _serve_from_pack(path: pathlib.Path, layer: int,
                     serve_masks: np.ndarray) -> tuple:
    store = FileNeuronStore(path, layer)
    eng = OffloadEngine.from_store(store, config=EngineConfig())
    t0 = time.perf_counter()
    eng.run_trace(serve_masks)
    wall = time.perf_counter() - t0
    s = eng.summary()
    hist = eng.history
    out = dict(
        extents=int(sum(t.io.measured_ops for t in hist)),
        modeled_io_ms_per_token=round(s["io_seconds_per_token"] * 1e3, 4),
        measured_io_ms_per_token=round(
            sum(t.io.measured_seconds for t in hist) / len(hist) * 1e3, 4),
        measured_mb_read=round(
            sum(t.io.measured_bytes for t in hist) / 1e6, 2),
        mean_run_length=round(s["mean_run_length"], 2),
        cache_hit_rate=round(s["cache_hit_rate"], 3),
        serve_wall_seconds=round(wall, 3),
    )
    store.close()
    return out, eng


def _modeled_identity_check(w, placement, pack_path) -> bool:
    """The file store's MODELED stats must be bit-identical to the in-memory
    store's on the same trace (the dual-accounting contract)."""
    e_mem = OffloadEngine(w["bundles"], placement=placement,
                          config=EngineConfig())
    e_mem.run_trace(w["serve_masks"])
    _, e_file = _serve_from_pack(pack_path, 0, w["serve_masks"])
    a, b = e_mem.summary(), e_file.summary()
    keys = ("io_seconds_per_token", "ops_per_token", "effective_bandwidth",
            "cache_hit_rate", "mean_run_length")
    return all(abs(a[k] - b[k]) <= 1e-12 * max(1.0, abs(a[k])) for k in keys)


def run(quick: bool) -> dict:
    w = _workload(quick)
    stats = stats_from_masks(w["calib_masks"])
    t0 = time.perf_counter()
    linked = search_placement(stats.distance_matrix(), mode="auto")
    search_seconds = time.perf_counter() - t0

    report = {"meta": {
        "quick": quick, "n_neurons": w["n_neurons"],
        "bundle_width_floats": w["width"],
        "calib_tokens": len(w["calib_masks"]),
        "serve_tokens": len(w["serve_masks"]),
        "search_seconds": round(search_seconds, 3),
    }}
    with tempfile.TemporaryDirectory(prefix="bench-pack-") as td:
        td = pathlib.Path(td)
        arms = {"identity": identity_placement(w["n_neurons"]),
                "linked": linked}
        for name, pl in arms.items():
            t0 = time.perf_counter()
            manifest = write_pack(td / f"{name}.npack", [w["bundles"]], [pl])
            report["meta"][f"{name}_pack_mb"] = round(
                manifest["file_bytes"] / 1e6, 2)
            report["meta"][f"{name}_pack_write_seconds"] = round(
                time.perf_counter() - t0, 3)
            report[name], _ = _serve_from_pack(td / f"{name}.npack", 0,
                                               w["serve_masks"])
        report["modeled_identity_checked"] = _modeled_identity_check(
            w, linked, td / "linked.npack")
    report["extent_ratio"] = round(
        report["identity"]["extents"] / max(report["linked"]["extents"], 1), 2)
    report["measured_speedup"] = round(
        report["identity"]["measured_io_ms_per_token"]
        / max(report["linked"]["measured_io_ms_per_token"], 1e-9), 2)
    report["caveat"] = (
        "measured_* fields count REAL positional file reads; in containers "
        "the page cache warms after the first pass, so the calibrated "
        "UFSDevice model stays the quantitative latency source")
    return report


def pack_io():
    """benchmarks/run.py suite entry: (name, us_per_call, derived) rows."""
    r = run(quick=True)
    rows = []
    for arm in ("identity", "linked"):
        rows.append((f"pack_io/{arm}_modeled_io_per_token",
                     r[arm]["modeled_io_ms_per_token"] * 1e3,
                     f"{r[arm]['extents']} real extents, "
                     f"run_len={r[arm]['mean_run_length']}"))
        rows.append((f"pack_io/{arm}_measured_file_io_per_token",
                     r[arm]["measured_io_ms_per_token"] * 1e3,
                     f"{r[arm]['measured_mb_read']}MB actually read"))
    rows.append(("pack_io/extent_ratio", r["extent_ratio"],
                 "identity extents / linked extents on the real file"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for the CI smoke run")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the linked layout issued <= "
                         "the identity layout's real extent reads AND the "
                         "file store's modeled stats matched the in-memory "
                         "store (both deterministic, unlike wall-clock)")
    ap.add_argument("--out", default="BENCH_pack.json")
    add_verbosity_flag(ap)
    args = ap.parse_args()
    configure_logging(args.verbose)

    report = run(args.quick)
    pathlib.Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    if args.check:
        if not report["modeled_identity_checked"]:
            sys.exit("file-store modeled stats diverged from the in-memory "
                     "NeuronStore — dual accounting broken")
        li, ident = report["linked"]["extents"], report["identity"]["extents"]
        if li > ident:
            sys.exit(f"linked layout issued MORE real file extents than "
                     f"identity ({li} > {ident}) — placement regressed")
        log.info("extent gate OK: linked %d <= identity %d real reads "
                 "(x%s fewer)", li, ident, report["extent_ratio"])


if __name__ == "__main__":
    main()
