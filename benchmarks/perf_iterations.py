"""§Perf hillclimb driver: run named variants of the three selected pairs and
report before/after roofline-relevant numbers.

Must run in its own process (forces 512 host devices like dryrun). Results go
to experiments/perf/ as JSON, one file per variant.

  PYTHONPATH=src python -m benchmarks.perf_iterations [--case A|B|C|extra]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
import argparse
import dataclasses
import json

from repro.launch.dryrun import run_case
from repro.launch.mesh import make_production_mesh


def _run(tag, arch, shape, overrides=None, options=None, microbatches=None, mesh=None):
    r = run_case(arch, shape, mesh=mesh, microbatches=microbatches,
                 config_overrides=overrides, options=options,
                 save_dir="experiments/perf", tag_suffix="_" + tag)
    row = {
        "variant": tag,
        "flops_body": r["cost_analysis"].get("flops", 0.0),
        "bytes_body": r["cost_analysis"].get("bytes accessed", 0.0),
        "coll_body": r["collective_bytes"].get("total", 0),
        "coll_by_kind": {k: v for k, v in r["collective_bytes"].items() if k != "total"},
        "temp_gib": r["memory_analysis"].get("temp_size_in_bytes", 0) / 2 ** 30,
        "arg_gib": r["memory_analysis"].get("argument_size_in_bytes", 0) / 2 ** 30,
        "compile_s": r["compile_seconds"],
    }
    print(f"  -> {tag}: flops={row['flops_body']:.3e} bytes={row['bytes_body']:.3e} "
          f"coll={row['coll_body']:.3e} temp={row['temp_gib']:.2f}GiB")
    return row


def case_A(mesh):
    """internlm2-20b x long_500k — the paper's regime (B=1 decode)."""
    print("== A: internlm2-20b x long_500k (memory-bound decode) ==")
    rows = [_run("A0_baseline_dense", "internlm2-20b", "long_500k", mesh=mesh)]
    rows.append(_run("A1_sparse_ffn", "internlm2-20b", "long_500k",
                     overrides=dict(serve_sparse=True, sparse_frac=0.15), mesh=mesh))
    rows.append(_run("A2_sparse_frac30", "internlm2-20b", "long_500k",
                     overrides=dict(serve_sparse=True, sparse_frac=0.30), mesh=mesh))
    return rows


def case_B(mesh):
    """jamba-1.5-large-398b x train_4k — compute-bound (worst fraction)."""
    print("== B: jamba x train_4k (compute-bound) ==")
    rows = [_run("B0_baseline", "jamba-1.5-large-398b", "train_4k", mesh=mesh)]
    import repro.configs as C
    cfg = C.get_config("jamba-1.5-large-398b", param_dtype="bfloat16",
                       compute_dtype="bfloat16")
    cfg_cf = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                              capacity_factor=1.05))
    from repro.launch.dryrun import build_lowered, _memory_analysis_dict, \
        _cost_analysis_dict, parse_collective_bytes
    import time
    lowered, _ = build_lowered("jamba-1.5-large-398b", "train_4k", mesh, cfg=cfg_cf)
    with mesh:
        t0 = time.perf_counter()
        compiled = lowered.compile()
        tc = time.perf_counter() - t0
    ca = _cost_analysis_dict(compiled)
    ma = _memory_analysis_dict(compiled)
    coll = parse_collective_bytes(compiled.as_text())
    row = {"variant": "B1_capacity_1.05", "flops_body": ca.get("flops", 0),
           "bytes_body": ca.get("bytes accessed", 0), "coll_body": coll.get("total", 0),
           "coll_by_kind": {k: v for k, v in coll.items() if k != "total"},
           "temp_gib": ma.get("temp_size_in_bytes", 0) / 2 ** 30,
           "arg_gib": ma.get("argument_size_in_bytes", 0) / 2 ** 30, "compile_s": tc}
    print(f"  -> B1_capacity_1.05: flops={row['flops_body']:.3e} "
          f"bytes={row['bytes_body']:.3e} coll={row['coll_body']:.3e} "
          f"temp={row['temp_gib']:.2f}GiB")
    rows.append(row)
    rows.append(_run("B2_triangular_flash", "jamba-1.5-large-398b", "train_4k",
                     overrides=dict(flash_triangular=True), mesh=mesh))
    return rows


def case_C(mesh):
    """xlstm-125m x prefill_32k — most collective-bound."""
    print("== C: xlstm x prefill_32k (collective-bound) ==")
    rows = [_run("C0_baseline", "xlstm-125m", "prefill_32k", mesh=mesh)]
    rows.append(_run("C1_replicate_small", "xlstm-125m", "prefill_32k",
                     options=dict(replicate_below=2_000_000), mesh=mesh))
    return rows


def case_extra(mesh):
    """Beyond-paper fixes measured on non-hillclimb pairs."""
    print("== extra: seamless train memory fix; decode cache S-sharding ==")
    rows = [_run("X0_seamless_train_flashxattn", "seamless-m4t-medium", "train_4k",
                 mesh=mesh)]
    rows.append(_run("X1_decode32k_baseline", "internlm2-20b", "decode_32k", mesh=mesh))
    rows.append(_run("X2_decode32k_shardseq", "internlm2-20b", "decode_32k",
                     options=dict(cache_shard_seq=True), mesh=mesh))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default="all", choices=["A", "B", "C", "extra", "all"])
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    os.makedirs("experiments/perf", exist_ok=True)
    all_rows = {}
    cases = {"A": case_A, "B": case_B, "C": case_C, "extra": case_extra}
    todo = cases if args.case == "all" else {args.case: cases[args.case]}
    for name, fn in todo.items():
        all_rows[name] = fn(mesh)
        with open(f"experiments/perf/summary_{name}.json", "w") as f:
            json.dump(all_rows[name], f, indent=2)
    print("done")


if __name__ == "__main__":
    main()
