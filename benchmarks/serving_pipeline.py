"""Batched + pipelined serving sweep: batch size x overlap x placement.

The paper's end-to-end latency win comes from three multiplicative effects:
placement/collapse shrink each read, batching merges reads across the decode
batch (shared neurons are read once), and double-buffered prefetch hides the
remaining I/O behind compute. This sweep isolates each axis on the simulated
UFS device and emits the paper-style per-token latency table.

Per-layer FFN compute is modeled from FLOPs at a fixed smartphone throughput
(2 * n_active * n_mats * d_model MACs at ``CPU_GFLOPS``), the same style of
accounting as the paper's latency breakdown; I/O comes from the engine's
device model. Rows report serial (compute + io) and overlapped latency.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import (N_SIM_LAYERS, Row, build_sim_model, make_engines,
                               model_geometry)
from repro.core.pipeline import IOScheduler

MODEL_ID = "opt-350m"       # smallest paper model: keeps the sweep fast
CPU_GFLOPS = 8.0            # effective smartphone big-core FP16 GEMV throughput
N_TOKENS = 60


def _ffn_compute_seconds(n_active: int, d_model: int, n_mats: int) -> float:
    flops = 2.0 * n_active * n_mats * d_model
    return flops / (CPU_GFLOPS * 1e9)


def _run_config(batch: int, system: str) -> dict:
    """One simulation per (system, batch): the scheduler's summary reports the
    serial and the overlapped latency of the same stage stream, so the
    overlap-off arm needs no second run. The decode loop drives the
    vectorized `step_masks` hot path (mask matrix straight to the engine);
    host wall-clock throughput of that loop rides along as `host_tok_s`."""
    sim = build_sim_model(MODEL_ID)
    _, n_mats, d_model, _, n_layers_real = model_geometry(MODEL_ID)
    engines = make_engines(sim, system)
    scheduler = IOScheduler(overlap=True)
    # one decode batch = `batch` independent mask streams per layer, advancing
    # in lockstep; request r's step-t mask is serve trace row (t + r*offset).
    offset = 7
    t_host = time.perf_counter()
    for t in range(N_TOKENS):
        scheduler.begin_token()
        for layer, eng in enumerate(engines):
            masks = sim.serve[layer]
            rows = [(t + r * offset) % masks.shape[0] for r in range(batch)]
            res = eng.step_masks(masks[rows], fetch_payload=False)
            # the batched FFN is a [batch, k_union] GEMM: every request
            # multiplies against the union payload
            compute = _ffn_compute_seconds(batch * res.merged.n_activated,
                                           d_model, n_mats)
            scheduler.record_stage(layer, compute, res.merged.io.seconds)
        scheduler.end_token()
    host_seconds = time.perf_counter() - t_host
    s = scheduler.summary()
    scale = n_layers_real / N_SIM_LAYERS
    return dict(
        serial=s["serial_seconds_per_token"] * scale,
        overlapped=s["overlapped_seconds_per_token"] * scale,
        efficiency=s["overlap_efficiency"],
        host_tok_s=N_TOKENS * batch / host_seconds,
    )


def serving_pipeline() -> List[Row]:
    rows: List[Row] = []
    for system in ("llmflash", "ripple"):
        for batch in (1, 2, 4):
            r = _run_config(batch, system)
            for tag, lat in (("serial", r["serial"]), ("overlap", r["overlapped"])):
                rows.append((
                    f"pipeline/{system}/b{batch}/{tag}",
                    lat * 1e6,
                    f"per-step latency; {lat / batch * 1e6:.0f}us/request"
                    + (f"; hidden {r['efficiency'] * 100:.1f}%"
                       f"; vs serial {r['serial'] * 1e6:.0f}us"
                       if tag == "overlap" else ""),
                ))
            rows.append((
                f"pipeline/{system}/b{batch}/host_tokens_per_s",
                r["host_tok_s"],
                "host wall-clock decode throughput of the engine loop "
                "(simulation driver time, not modeled latency)",
            ))
    return rows
