"""Batched + pipelined serving sweep: batch size x overlap x placement,
plus the MEASURED prefetch-on/off end-to-end decode comparison.

The paper's end-to-end latency win comes from three multiplicative effects:
placement/collapse shrink each read, batching merges reads across the decode
batch (shared neurons are read once), and double-buffered prefetch hides the
remaining I/O behind compute. This sweep isolates each axis on the simulated
UFS device and emits the paper-style per-token latency table.

Per-layer FFN compute is modeled from FLOPs at a fixed smartphone throughput
(2 * n_active * n_mats * d_model MACs at ``CPU_GFLOPS``), the same style of
accounting as the paper's latency breakdown; I/O comes from the engine's
device model. Rows report serial (compute + io) and overlapped latency.

Run standalone to EXECUTE the overlap instead of modeling it: the e2e arm
drives `ServingEngine(mode="offload")` with prefetch off (serial engine work
on the decode critical path) and on (background I/O worker fed by the trained
cross-layer lookahead, mis-predictions topped up synchronously), measures
host decode tokens/s for both, checks oracle-lookahead token identity, times
the offline placement search with the reference vs batched greedy loop, and
writes ``BENCH_prefetch.json``:

  PYTHONPATH=src python benchmarks/serving_pipeline.py [--quick] [--check]

A second arm benchmarks the slot-based continuous-batching server against the
historic length-grouped lockstep path on a mixed-prompt-length Poisson-arrival
workload and writes ``BENCH_serving.json``. A third (`paged_kv`) compares the
paged KV cache against contiguous preallocated slots at the SAME KV memory
budget — concurrent-request headroom, shared-prefix CoW token identity, and
page-pressure preemption with full reclamation (see `bench_paged_kv`).

``--check`` is the CI gate: non-zero exit unless pipelined decode tokens/s
>= serial within tolerance AND the oracle arm is token-identical to serial
AND the auto-resolved FFN kernel (the fused segment path on searched
layouts) is token-identical to the forced-bundles arm AND the fresh
engine-loop overlap efficiency >= --efficiency-tolerance x the committed
BENCH_prefetch.json value (read before the fresh run overwrites it) AND
continuous-batching tokens/s >= --serving-tolerance x length-grouped AND
the paged-KV arm holds: concurrency >= --paged-concurrency-floor x the
contiguous baseline at equal budget with byte-identical tokens, zero
clean-path CoW/preemption counters, CoW-diverged fork identity, and
pressure-arm preemption with exact partial prefixes + page conservation.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from typing import List

import numpy as np

if __package__ in (None, ""):                     # standalone script mode
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from benchmarks.common import (N_SIM_LAYERS, Row, build_sim_model, make_engines,
                               model_geometry)
from repro.core.pipeline import IOScheduler
from repro.utils import add_verbosity_flag, configure_logging, get_logger

log = get_logger("bench.serving")


MODEL_ID = "opt-350m"       # smallest paper model: keeps the sweep fast
CPU_GFLOPS = 8.0            # effective smartphone big-core FP16 GEMV throughput
N_TOKENS = 60


def _ffn_compute_seconds(n_active: int, d_model: int, n_mats: int) -> float:
    flops = 2.0 * n_active * n_mats * d_model
    return flops / (CPU_GFLOPS * 1e9)


def _run_config(batch: int, system: str) -> dict:
    """One simulation per (system, batch): the scheduler's summary reports the
    serial and the overlapped latency of the same stage stream, so the
    overlap-off arm needs no second run. The decode loop drives the
    vectorized `step_masks` hot path (mask matrix straight to the engine);
    host wall-clock throughput of that loop rides along as `host_tok_s`."""
    sim = build_sim_model(MODEL_ID)
    _, n_mats, d_model, _, n_layers_real = model_geometry(MODEL_ID)
    engines = make_engines(sim, system)
    scheduler = IOScheduler(overlap=True)
    # one decode batch = `batch` independent mask streams per layer, advancing
    # in lockstep; request r's step-t mask is serve trace row (t + r*offset).
    offset = 7
    t_host = time.perf_counter()
    for t in range(N_TOKENS):
        scheduler.begin_token()
        for layer, eng in enumerate(engines):
            masks = sim.serve[layer]
            rows = [(t + r * offset) % masks.shape[0] for r in range(batch)]
            res = eng.step_masks(masks[rows], fetch_payload=False)
            # the batched FFN is a [batch, k_union] GEMM: every request
            # multiplies against the union payload
            compute = _ffn_compute_seconds(batch * res.merged.n_activated,
                                           d_model, n_mats)
            scheduler.record_stage(layer, compute, res.merged.io.seconds)
        scheduler.end_token()
    host_seconds = time.perf_counter() - t_host
    s = scheduler.summary()
    scale = n_layers_real / N_SIM_LAYERS
    return dict(
        serial=s["serial_seconds_per_token"] * scale,
        overlapped=s["overlapped_seconds_per_token"] * scale,
        efficiency=s["overlap_efficiency"],
        host_tok_s=N_TOKENS * batch / host_seconds,
    )


def serving_pipeline() -> List[Row]:
    rows: List[Row] = []
    for system in ("llmflash", "ripple"):
        for batch in (1, 2, 4):
            r = _run_config(batch, system)
            for tag, lat in (("serial", r["serial"]), ("overlap", r["overlapped"])):
                rows.append((
                    f"pipeline/{system}/b{batch}/{tag}",
                    lat * 1e6,
                    f"per-step latency; {lat / batch * 1e6:.0f}us/request"
                    + (f"; hidden {r['efficiency'] * 100:.1f}%"
                       f"; vs serial {r['serial'] * 1e6:.0f}us"
                       if tag == "overlap" else ""),
                ))
            rows.append((
                f"pipeline/{system}/b{batch}/host_tokens_per_s",
                r["host_tok_s"],
                "host wall-clock decode throughput of the engine loop "
                "(simulation driver time, not modeled latency)",
            ))
    # prefetch on/off: the MEASURED executed-overlap arm (engine decode loop)
    pf = bench_prefetch_engine_loop(quick=True)
    for tag in ("serial", "pipelined"):
        rows.append((
            f"prefetch/engine_loop/{tag}_tokens_per_s",
            pf[f"{tag}_tokens_per_s"],
            "measured decode throughput of the offload engine layer loop "
            + ("with the async layer-ahead prefetch worker"
               if tag == "pipelined" else "with serial engine steps")
            + " (emulated device latency, linked layout)",
        ))
    rows.append((
        "prefetch/engine_loop/measured_hidden_us_per_token",
        pf["measured"]["hidden_seconds_per_token"] * 1e6,
        f"I/O host+device time hidden behind compute; efficiency "
        f"{pf['measured']['overlap_efficiency'] * 100:.1f}%",
    ))
    return rows


# ---------------------------------------------------------------------------
# Executed overlap: end-to-end prefetch on/off (BENCH_prefetch.json)
# ---------------------------------------------------------------------------

def _decode_tokens_per_s(results) -> float:
    new_tokens = sum(len(r.tokens) for r in results)
    return new_tokens / max(max(r.decode_seconds for r in results), 1e-12)


def bench_prefetch_engine_loop(quick: bool = False) -> dict:
    """EXECUTED overlap, isolated to the storage pipeline: the engine-driven
    decode layer loop (the same loop shape as BENCH_hotpath's serving_decode)
    with prefetch off vs on, under temporally-faithful device emulation.

    Serial: per layer, the engine step stalls on the emulated flash read
    (`EngineConfig.emulate_read_latency` — the modeled UFS read time is
    actually waited out, exactly as a real link would stall the pipeline),
    then the sparse FFN computes, then the next layer's step begins — the
    layer dependency is enforced by blocking on each layer's FFN output.
    Pipelined: the I/O worker serves layer k+1's begin phase (probe + read
    stall + staging gather) while the serving thread blocks on layer k's FFN
    compute — a sleeping worker costs no CPU, so the flash stall is hidden
    even on a saturated host. Three arms: serial, pipelined with exact
    lookahead (the speculation upper bound), and pipelined with a degraded
    lookahead (10% of true neurons dropped + 2% random noise added) that
    exercises the synchronous top-up path every layer.

    Geometry: n=8192 neurons/block on the linked (cluster-contiguous) layout,
    fp16-bundle I/O accounting (`bundle_bytes=8192`, a d_model≈2k 2-matrix
    model) over a reduced f32 compute payload — the same accounting split
    benchmarks/common.py uses. The compute payload is d=512 per matrix: the
    fused segment kernel (the `ffn_kernel="auto"` default on this linked
    layout) cut the per-layer host glue to a fraction of the bundles path,
    so a thinner payload leaves almost no layer-k compute to hide layer
    k+1's flash stall behind — d=512 restores a realistic compute window
    for the same modeled I/O (`bundle_bytes` fixes the accounting; the
    payload dim only sets how much real FFN work the device does).
    """
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.engine import EngineConfig
    from repro.core.placement import PlacementResult
    from repro.core.trace import SyntheticTraceConfig, synthetic_masks
    from repro.serving.engine import OffloadedFFNRuntime

    # quick mode trims tokens/repeats, not geometry — below ~8k neurons the
    # per-layer flash stall is too small to measure the overlap against
    n, d, L, batch = 8192, 512, 2, 8
    T, warm = (12, 8) if quick else (24, 10)
    repeats = 2 if quick else 3
    n_clusters = 64

    struct_rng = np.random.default_rng(0)
    perm = struct_rng.permutation(n)
    cluster_of = np.empty(n, dtype=np.int64)
    for c in range(n_clusters):
        cluster_of[perm[c::n_clusters]] = c
    order = np.argsort(cluster_of, kind="stable")
    inv = np.empty(n, dtype=np.int64)
    inv[order] = np.arange(n)
    pl = PlacementResult(order, inv, 0, 0.0, "bench-linked")

    cfg = get_config("opt-350m", reduced=True, d_model=d, d_ff=n,
                     vocab_size=128)
    masks = [synthetic_masks(
        SyntheticTraceConfig(n_neurons=n, n_clusters=n_clusters,
                             clusters_per_token=7, member_p=0.9, noise_p=0.005,
                             zipf_alpha=1.1, seed=l, structure_seed=0),
        T + warm) for l in range(L)]

    def bm(layer, t):
        return masks[layer][[(t + r * 7) % (T + warm) for r in range(batch)]]

    rng = np.random.default_rng(2)
    bundles = rng.standard_normal((n, 2 * d)).astype(np.float32)
    ecfg = EngineConfig(emulate_read_latency=True)
    h = jnp.asarray(rng.standard_normal((batch, d)).astype(np.float32))

    def make_rt():
        return OffloadedFFNRuntime(cfg, [bundles] * L, [pl] * L,
                                   engine_cfg=ecfg, bundle_bytes=8192)

    def serial_run(rt, lo, hi):
        t0 = time.perf_counter()
        for t in range(lo, hi):
            for layer in range(L):
                y, _ = rt.ffn_apply_batch(layer, h, bm(layer, t))
                y.block_until_ready()     # layer k+1's mask depends on y
        return (hi - lo) * batch / (time.perf_counter() - t0)

    def pipe_run(rt, lo, hi, scheduler=None, degrade_rng=None):
        def spec_of(m):
            if degrade_rng is None:
                return m
            s = m & (degrade_rng.random(m.shape) > 0.1)   # drop 10%
            return s | (degrade_rng.random(m.shape) < 0.02)  # add 2% noise
        rt.start_prefetch()
        t0 = time.perf_counter()
        try:
            for t in range(lo, hi):
                tok0 = time.perf_counter()
                if scheduler is not None:
                    scheduler.begin_token()
                rt.begin_layer(0, spec_of(bm(0, t)))
                for layer in range(L):
                    if layer + 1 < L:
                        rt.begin_layer(layer + 1, spec_of(bm(layer + 1, t)))
                    y, res, meas = rt.complete_layer(layer, h, bm(layer, t))
                    y.block_until_ready()
                    if scheduler is not None:
                        scheduler.record_stage(layer,
                                               io_seconds=res.merged.io.seconds,
                                               flops=1.0, measured=meas)
                if scheduler is not None:
                    scheduler.end_token(
                        wall_seconds=time.perf_counter() - tok0)
        finally:
            rt.stop_prefetch()
        return (hi - lo) * batch / (time.perf_counter() - t0)

    rt_s, rt_p, rt_d = make_rt(), make_rt(), make_rt()
    serial_run(rt_s, 0, warm)
    pipe_run(rt_p, 0, warm)
    pipe_run(rt_d, 0, warm, degrade_rng=np.random.default_rng(9))
    best = {"serial": 0.0, "pipelined": 0.0, "degraded": 0.0}
    sched = IOScheduler(overlap=True)
    summary = None
    for _ in range(repeats):                     # arms interleaved per repeat
        for rt in (rt_s, rt_p, rt_d):            # per-repeat counters: the
            rt.reset_stats()                     # reported topup covers ONE
        best["serial"] = max(best["serial"], serial_run(rt_s, warm, warm + T))
        sched.reset()
        tok_s = pipe_run(rt_p, warm, warm + T, scheduler=sched)
        if tok_s > best["pipelined"]:
            best["pipelined"] = tok_s
            summary = sched.summary()
        best["degraded"] = max(best["degraded"], pipe_run(
            rt_d, warm, warm + T, degrade_rng=np.random.default_rng(9)))
    return {
        "serial_tokens_per_s": round(best["serial"], 1),
        "pipelined_tokens_per_s": round(best["pipelined"], 1),
        "degraded_lookahead_tokens_per_s": round(best["degraded"], 1),
        "improvement": round(best["pipelined"] / best["serial"], 3),
        "degraded_improvement": round(best["degraded"] / best["serial"], 3),
        "ffn_kernel": rt_s.ffn_kernel,
        "topup_neurons_total": rt_d.topup_total,
        "measured": {
            "wall_seconds_per_token": summary["measured_wall_seconds_per_token"],
            "serial_seconds_per_token": summary["measured_serial_seconds_per_token"],
            "hidden_seconds_per_token": summary["measured_hidden_seconds_per_token"],
            "exposed_seconds_per_token": summary["measured_exposed_seconds_per_token"],
            "io_busy_seconds_per_token": summary["measured_io_busy_seconds_per_token"],
            "overlap_efficiency": summary["measured_overlap_efficiency"],
        },
        "meta": {
            "n_neurons": n, "d_payload": d, "n_layers": L, "batch": batch,
            "tokens": T, "repeats": repeats, "bundle_bytes": 8192,
            "device": "UFS4.0 (emulated latency)", "layout": "linked",
        },
    }


def bench_prefetch_e2e(quick: bool = False) -> dict:
    """Serial vs pipelined offload decode through the full ServingEngine.

    Serial decode pays (device compute) + (host engine work) per layer on one
    thread; pipelined decode runs the engine work for layer k+1 on the I/O
    worker (driven by the trained cross-layer lookahead) while the device
    computes layer k. Both arms serve identical requests on the LINKED layout
    (co-activation placement). An oracle-lookahead arm checks token identity
    against serial; the lookahead arm's tokens are compared as well.

    NOTE on throughput: on a CPU-only host the e2e decode loop is dominated
    by eager per-op dispatch (GIL-held Python), which leaves the worker
    little true concurrency to exploit — the tokens/s columns here are
    reported for transparency, while the engine-loop benchmark above
    isolates the storage pipeline where the overlap actually executes. The
    token-identity columns are the correctness acceptance.

    Methodology: one full-length warmup serve per arm (compiles every
    pad-bucket FFN shape), then the arms are timed back to back inside each
    repeat so host-load drift cancels out of the ratio; the reported number
    is each arm's best repeat (same convention as engine_hotpath).

    A fourth arm forces `ffn_kernel="bundles"` on the same searched layout
    and the same requests: the serial arm's auto-resolved kernel (segments,
    since the layout is placement-ordered) must produce bit-identical tokens
    — `kernel_token_identical` is part of the `--check` gate.
    """
    import jax
    from repro.configs import get_config
    from repro.core.engine import EngineConfig
    from repro.models import build_model
    from repro.serving.engine import (Request, ServingEngine,
                                      build_offload_runtime)

    d_model, d_ff = 192, 2048      # engine host work ~ per-layer FFN compute
    n_tokens = 12 if quick else 24
    repeats = 2 if quick else 4
    batch = 4
    cfg = get_config("opt-350m", reduced=True, d_model=d_model, d_ff=d_ff,
                     n_layers=2, vocab_size=512)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, 512, 16).astype(np.int32),
                    max_new_tokens=n_tokens) for i in range(batch)]

    t0 = time.perf_counter()
    rt_serial = build_offload_runtime(model, params,
                                      rng=np.random.default_rng(1))
    calib_seconds = time.perf_counter() - t0
    rt_oracle = build_offload_runtime(model, params,
                                      rng=np.random.default_rng(1))
    rt_pipe = build_offload_runtime(model, params,
                                    rng=np.random.default_rng(1),
                                    train_lookahead=True)
    rt_bundles = build_offload_runtime(
        model, params, rng=np.random.default_rng(1),
        engine_cfg=EngineConfig(ffn_kernel="bundles"))
    engines = {
        "serial": ServingEngine(model, params, max_len=n_tokens + 24,
                                mode="offload", offload=rt_serial),
        "bundles": ServingEngine(model, params, max_len=n_tokens + 24,
                                 mode="offload", offload=rt_bundles),
        "oracle": ServingEngine(model, params, max_len=n_tokens + 24,
                                mode="offload", offload=rt_oracle,
                                prefetch=True, lookahead="oracle"),
        "pipelined": ServingEngine(model, params, max_len=n_tokens + 24,
                                   mode="offload", offload=rt_pipe,
                                   prefetch=True),
    }
    best = {name: 0.0 for name in engines}
    tokens = {}
    summaries = {}
    for name, eng in engines.items():            # full-length compile warmup
        tokens[name] = [r.tokens for r in eng.serve(reqs)]
    for _ in range(repeats):                     # arms interleaved per repeat
        for name, eng in engines.items():
            eng.offload.reset_stats()
            eng.scheduler.reset()
            res = eng.serve(reqs)
            tok_s = _decode_tokens_per_s(res)
            if tok_s > best[name]:
                best[name] = tok_s
                summaries[name] = eng.scheduler.summary()

    s = summaries["pipelined"]
    return {
        "serial_tokens_per_s": round(best["serial"], 1),
        "pipelined_tokens_per_s": round(best["pipelined"], 1),
        "oracle_tokens_per_s": round(best["oracle"], 1),
        "bundles_kernel_tokens_per_s": round(best["bundles"], 1),
        "improvement": round(best["pipelined"] / best["serial"], 3),
        "oracle_token_identical": tokens["serial"] == tokens["oracle"],
        "lookahead_token_identical": tokens["serial"] == tokens["pipelined"],
        "auto_ffn_kernel": rt_serial.ffn_kernel,
        "kernel_token_identical": tokens["serial"] == tokens["bundles"],
        "measured": {
            "wall_seconds_per_token": s["measured_wall_seconds_per_token"],
            "serial_seconds_per_token": s["measured_serial_seconds_per_token"],
            "hidden_seconds_per_token": s["measured_hidden_seconds_per_token"],
            "exposed_seconds_per_token": s["measured_exposed_seconds_per_token"],
            "io_busy_seconds_per_token": s["measured_io_busy_seconds_per_token"],
            "overlap_efficiency": s["measured_overlap_efficiency"],
        },
        "modeled_overlap_efficiency": s["overlap_efficiency"],
        "topup_neurons_total": rt_pipe.topup_total,
        "calibration_seconds": round(calib_seconds, 2),
        "meta": {
            "d_model": d_model, "d_ff": d_ff,
            "n_layers": cfg.n_layers, "batch": batch, "repeats": repeats,
            "new_tokens_per_request": n_tokens, "layout": "linked (placement)",
        },
    }




def bench_continuous_batching(quick: bool = False, seed: int = 0) -> dict:
    """Continuous batching vs length-grouped lockstep decode (BENCH_serving).

    Workload: mixed prompt lengths x mixed max_new_tokens with Poisson
    arrivals (arrival clock measured in decode steps, so the schedule is
    deterministic and no real sleeping pollutes the timing).

      * continuous — one slot-based InferenceServer: requests are admitted
        into freed slots mid-flight and retire individually, so a slot never
        burns steps on a finished request;
      * grouped — the historic ServingEngine behavior, emulated on the same
        machinery for a fair per-step cost: one server per exact prompt
        length, every request decoded in lockstep to the GROUP's max
        max_new_tokens (extra tokens discarded), groups served sequentially,
        all requests available up front (which only flatters this baseline).

    Both arms share one jitted decode (slot count == group size), produce the
    same useful tokens, and report decode-only throughput: useful decode
    tokens / summed decode-iteration wall. The grouped arm's waste is
    structural — lockstep slot-steps for already-finished requests and no
    cross-length sharing — so continuous wins on efficiency, not noise.
    """
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.engine import Request
    from repro.serving.server import InferenceServer

    if quick:
        lengths, new_tok = (8, 16), (4, 6, 10, 16)
    else:
        lengths, new_tok = (8, 16, 24), (6, 10, 18, 30)
    slots = len(new_tok)
    max_len = max(lengths) + max(new_tok) + 2
    repeats = 2 if quick else 3
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=256)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=g * len(new_tok) + i,
                    prompt=rng.integers(0, 256, T).astype(np.int32),
                    max_new_tokens=n)
            for g, T in enumerate(lengths) for i, n in enumerate(new_tok)]
    # Poisson arrivals at ~1 request per decode step, in submission order
    arrivals = np.cumsum(rng.exponential(1.0, len(reqs)))
    useful_decode_tokens = sum(r.max_new_tokens - 1 for r in reqs)
    # one shared jitted decode: every server below runs slot count == `slots`,
    # so no arm pays a recompile inside its timed region
    decode_fn = jax.jit(lambda p, t, pos, c: model.decode_step(p, t, pos, c))

    def run_continuous() -> dict:
        server = InferenceServer(model, params, max_slots=slots,
                                 max_len=max_len, seed=seed,
                                 decode_fn=decode_fn)
        i = 0
        while i < len(reqs) or server.has_work:
            while i < len(reqs) and arrivals[i] <= server.stats.decode_steps:
                server.submit(reqs[i])
                i += 1
            if server.has_work:
                server.step()
            else:                      # idle: jump the clock to the arrival
                server.submit(reqs[i])
                i += 1
        st = server.stats
        return dict(decode_seconds=st.decode_seconds,
                    decode_steps=st.decode_steps, occupancy=st.occupancy,
                    tokens_per_s=useful_decode_tokens / st.decode_seconds)

    def run_grouped() -> dict:
        decode_seconds = 0.0
        decode_steps = slot_steps = 0
        by_len = {}
        for r in reqs:
            by_len.setdefault(len(r.prompt), []).append(r)
        for group in by_len.values():
            lockstep = max(r.max_new_tokens for r in group)
            server = InferenceServer(model, params, max_slots=len(group),
                                     max_len=max_len, seed=seed,
                                     decode_fn=decode_fn)
            for r in group:            # every request decodes to the group max
                server.submit(Request(uid=r.uid, prompt=r.prompt,
                                      max_new_tokens=lockstep))
            server.drain()
            decode_seconds += server.stats.decode_seconds
            decode_steps += server.stats.decode_steps
            slot_steps += server.stats.slot_steps_active
        return dict(decode_seconds=decode_seconds, decode_steps=decode_steps,
                    occupancy=slot_steps / max(decode_steps * slots, 1),
                    tokens_per_s=useful_decode_tokens / decode_seconds)

    run_continuous(), run_grouped()                   # compile warmup
    best = {"continuous": None, "grouped": None}
    for _ in range(repeats):                          # arms interleaved
        for name, fn in (("continuous", run_continuous),
                         ("grouped", run_grouped)):
            r = fn()
            if best[name] is None or r["tokens_per_s"] > best[name]["tokens_per_s"]:
                best[name] = r
    return {
        "continuous": {k: round(v, 4) for k, v in best["continuous"].items()},
        "grouped": {k: round(v, 4) for k, v in best["grouped"].items()},
        "speedup": round(best["continuous"]["tokens_per_s"]
                         / best["grouped"]["tokens_per_s"], 3),
        "meta": {
            "arch": "granite-3-2b (reduced)", "slots": slots,
            "prompt_lengths": list(lengths), "max_new_tokens": list(new_tok),
            "n_requests": len(reqs), "useful_decode_tokens": useful_decode_tokens,
            "arrivals": "Poisson, ~1 request/decode-step, grouped arm exempt",
            "repeats": repeats,
        },
    }


def bench_paged_kv(quick: bool = False, seed: int = 0) -> dict:
    """Paged KV cache vs preallocated contiguous slots at the SAME KV memory
    budget (the `paged_kv` section of BENCH_serving.json).

    Three sub-arms, all on one reduced attention-only decoder stack:

      * concurrency — the headline claim: a contiguous server must
        preallocate `max_len` KV positions per slot, so a 192-position
        budget buys `192 // max_len` slots; the paged server spends the
        same 192 positions as on-demand pages and admits every request
        whose COMMITTED worst case (prompt + max_new, page-rounded) still
        fits, so short requests pack the arena. Peak concurrent requests
        are counted per decode step on both servers; tokens must be
        byte-identical per uid (grouping-invariant sampling makes the
        contiguous run the ground truth), and the clean-path counters
        (CoW copies, preemptions) must be exactly zero — no hidden cost
        when nothing is shared and nothing is evicted.
      * shared_prefix — CoW correctness under live-prompt forking: a
        request whose prompt extends a LIVE request's full prompt shares
        its pages (including the partial last page) and diverges via
        copy-on-write; both must finish token-identical to a contiguous
        run of the same requests.
      * pressure — an overcommitted pool too small for every admitted
        request's worst case: preemption must engage, preempted partial
        outputs must be exact prefixes of the unconstrained run, and after
        drain + registry clear the free list must hold every page
        (allocated == freed: no leaks on any retirement path).
    """
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.engine import Request
    from repro.serving.server import InferenceServer

    page_size, num_pages = 8, 24          # 192 KV positions per sublayer
    max_len = 96                          # contiguous per-slot preallocation
    base_slots = (num_pages * page_size) // max_len        # same budget: 2
    prompt_len, new_tokens = 10, 6        # 16 positions -> 2 pages committed
    n_req = 12                            # 12 x 2 pages == the whole arena
    cfg = get_config("opt-350m", reduced=True, d_model=64, d_ff=256,
                     n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, 128, prompt_len).astype(np.int32),
                    max_new_tokens=new_tokens) for i in range(n_req)]

    def drive(server, requests, staged=()):
        """Submit-all + step to drain, tracking peak concurrent actives.
        `staged` entries (after_step, request) submit mid-flight."""
        handles = [server.submit(r) for r in requests]
        pending = list(staged)
        peak = steps = 0
        while server.has_work or pending:
            if not server.has_work and pending:
                _, r = pending.pop(0)
                handles.append(server.submit(r))
                continue
            server.step()
            steps += 1
            peak = max(peak, int(server._active_mask().sum()))
            while pending and pending[0][0] <= steps:
                handles.append(server.submit(pending.pop(0)[1]))
        return handles, peak

    # -- concurrency at fixed budget ----------------------------------------
    base = InferenceServer(model, params, max_slots=base_slots,
                           max_len=max_len, seed=seed)
    base_handles, base_peak = drive(base, reqs)
    ref = {h.uid: list(h.tokens) for h in base_handles}
    bst = base.stats
    paged = InferenceServer(model, params, max_slots=n_req + 4,
                            max_len=max_len, seed=seed,
                            page_size=page_size, num_pages=num_pages)
    paged_handles, paged_peak = drive(paged, reqs)
    pst = paged.stats
    psum = paged.page_summary()
    concurrency = {
        "baseline_peak_concurrent": base_peak,
        "paged_peak_concurrent": paged_peak,
        "concurrency_ratio": round(paged_peak / max(base_peak, 1), 2),
        "tokens_identical": all(list(h.tokens) == ref[h.uid]
                                for h in paged_handles),
        "all_finished_length": all(h.finish_reason == "length"
                                   for h in paged_handles),
        "cow_copies": psum["cow_copies"],
        "preemptions": psum["preemptions"],
        "page_deferrals": psum["page_deferrals"],
        "peak_page_occupancy": psum["peak_page_occupancy"],
        "baseline_tokens_per_s": round(
            bst.tokens_emitted / max(bst.decode_seconds, 1e-9), 1),
        "paged_tokens_per_s": round(
            pst.tokens_emitted / max(pst.decode_seconds, 1e-9), 1),
        "baseline_decode_steps": bst.decode_steps,
        "paged_decode_steps": pst.decode_steps,
    }

    # -- shared-prefix CoW divergence ---------------------------------------
    base_prompt = rng.integers(0, 128, 12).astype(np.int32)   # partial page 2
    fork_reqs = [
        Request(uid=100, prompt=base_prompt, max_new_tokens=new_tokens),
        Request(uid=101,
                prompt=np.concatenate([base_prompt, [7]]).astype(np.int32),
                max_new_tokens=new_tokens),
        Request(uid=102,
                prompt=np.concatenate([base_prompt, [9, 3]]).astype(np.int32),
                max_new_tokens=new_tokens),
    ]
    ref_srv = InferenceServer(model, params, max_slots=len(fork_reqs),
                              max_len=max_len, seed=seed)
    fork_ref, _ = drive(ref_srv, fork_reqs)
    fork_expect = {h.uid: list(h.tokens) for h in fork_ref}
    fork_srv = InferenceServer(model, params, max_slots=len(fork_reqs),
                               max_len=max_len, seed=seed,
                               page_size=page_size, num_pages=num_pages)
    # submit the parent alone, decode two steps, then fork the children off
    # its live pages — the partial last page diverges via copy-on-write
    fork_handles, _ = drive(fork_srv, fork_reqs[:1],
                            staged=[(2, fork_reqs[1]), (2, fork_reqs[2])])
    fsum = fork_srv.page_summary()
    shared_prefix = {
        "tokens_identical": all(list(h.tokens) == fork_expect[h.uid]
                                for h in fork_handles),
        "cow_copies": fsum["cow_copies"],
        "pages_shared": fsum["pages_shared"],
        "prefix_hits": fsum["prefix_hits"],
        "preemptions": fsum["preemptions"],
    }

    # -- page pressure: overcommit + preemption + reclamation ---------------
    p_size, p_pages = 4, 10
    press_reqs = [Request(uid=200 + i,
                          prompt=rng.integers(0, 128, 6).astype(np.int32),
                          max_new_tokens=10) for i in range(6)]
    ref_srv = InferenceServer(model, params, max_slots=len(press_reqs),
                              max_len=max_len, seed=seed)
    press_ref, _ = drive(ref_srv, press_reqs)
    press_expect = {h.uid: list(h.tokens) for h in press_ref}
    press_srv = InferenceServer(model, params, max_slots=4, max_len=max_len,
                                seed=seed, page_size=p_size, num_pages=p_pages,
                                page_overcommit=True)
    press_handles, _ = drive(press_srv, press_reqs)
    pool = press_srv._pool
    pool.clear_prefix_cache()
    pool.check()
    ssum = press_srv.page_summary()
    finished = [h for h in press_handles if h.finish_reason == "length"]
    preempted = [h for h in press_handles if h.finish_reason == "preempted"]
    pressure = {
        "preemptions": ssum["preemptions"],
        "page_deferrals": ssum["page_deferrals"],
        "n_finished": len(finished),
        "n_preempted": len(preempted),
        "finished_identical": all(list(h.tokens) == press_expect[h.uid]
                                  for h in finished),
        "partial_prefix_identical": all(
            list(h.tokens) == press_expect[h.uid][:len(h.tokens)]
            for h in preempted),
        "pages_reclaimed": pool.n_free == p_pages,
        "alloc_freed_balanced":
            pool.stats.pages_allocated == pool.stats.pages_freed,
    }

    return {
        "budget": {
            "kv_positions": num_pages * page_size,
            "page_size": page_size, "num_pages": num_pages,
            "contiguous_slots": base_slots, "contiguous_max_len": max_len,
        },
        "concurrency": concurrency,
        "shared_prefix": shared_prefix,
        "pressure": pressure,
        "meta": {
            "arch": "opt-350m (reduced, d_model=64)",
            "prompt_len": prompt_len, "new_tokens": new_tokens,
            "n_requests": n_req, "quick": quick,
        },
    }


def bench_placement_search(quick: bool = False) -> dict:
    """Offline placement search: reference per-edge greedy loop vs the
    batched array-native implementation (bit-identical placements asserted
    while timing) — the satellite's before/after `search_seconds`."""
    from repro.core.coactivation import stats_from_masks
    from repro.core.placement import search_placement
    from repro.core.trace import SyntheticTraceConfig, synthetic_masks

    n = 1024 if quick else 4096
    tcfg = SyntheticTraceConfig(n_neurons=n, n_clusters=64, seed=7)
    masks = synthetic_masks(tcfg, 100 if quick else 200)
    dist = stats_from_masks(masks).distance_matrix()
    batched = search_placement(dist, mode="exact", greedy_impl="batched")
    loop = search_placement(dist, mode="exact", greedy_impl="loop")
    assert np.array_equal(batched.placement, loop.placement), \
        "batched placement diverged from the reference loop"
    return {
        "n_neurons": n,
        "reference_search_seconds": round(loop.search_seconds, 3),
        "batched_search_seconds": round(batched.search_seconds, 3),
        "speedup": round(loop.search_seconds / batched.search_seconds, 2),
        "bit_identical": True,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for the CI smoke run")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless pipelined decode tokens/s >= "
                         "serial within tolerance and the oracle-lookahead "
                         "arm is token-identical to serial (the CI gate)")
    ap.add_argument("--tolerance", type=float, default=0.85,
                    help="--check passes if pipelined >= tolerance * serial "
                         "(shared CI runners are noisy; the committed "
                         "BENCH_prefetch.json shows the real improvement)")
    ap.add_argument("--serving-tolerance", type=float, default=1.0,
                    help="--check passes if continuous-batching decode "
                         "tokens/s >= this x length-grouped tokens/s (the "
                         "committed BENCH_serving.json shows the real margin)")
    ap.add_argument("--efficiency-tolerance", type=float, default=0.5,
                    help="--check passes if the fresh engine-loop measured "
                         "overlap_efficiency >= this x the committed "
                         "BENCH_prefetch.json value (guards the fused-kernel "
                         "hot path against glue creep; loose because shared "
                         "CI runners overlap far worse than the committed "
                         "dedicated-host run)")
    ap.add_argument("--paged-concurrency-floor", type=float, default=4.0,
                    help="--check fails unless the paged-KV server sustains "
                         "at least this many times the concurrent requests "
                         "of the contiguous-slot baseline at the same KV "
                         "memory budget (deterministic: counts slots, not "
                         "wall-clock)")
    ap.add_argument("--out", default="BENCH_prefetch.json")
    ap.add_argument("--serving-out", default="BENCH_serving.json")
    add_verbosity_flag(ap)
    args = ap.parse_args()
    configure_logging(args.verbose)

    # read the committed baseline BEFORE the fresh run overwrites --out
    committed_eff = None
    committed = pathlib.Path(args.out)
    if committed.exists():
        try:
            committed_eff = json.loads(committed.read_text())[
                "engine_loop"]["measured"]["overlap_efficiency"]
        except (json.JSONDecodeError, KeyError, TypeError):
            committed_eff = None

    report = {
        "engine_loop": bench_prefetch_engine_loop(quick=args.quick),
        "e2e": bench_prefetch_e2e(quick=args.quick),
        "placement_search": bench_placement_search(quick=args.quick),
        "quick": args.quick,
    }
    pathlib.Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    serving = dict(bench_continuous_batching(quick=args.quick),
                   paged_kv=bench_paged_kv(quick=args.quick),
                   quick=args.quick)
    pathlib.Path(args.serving_out).write_text(
        json.dumps(serving, indent=2) + "\n")
    print(json.dumps({**report, "continuous_batching": serving}, indent=2))
    if args.check:
        el, e2e = report["engine_loop"], report["e2e"]
        if not e2e["oracle_token_identical"]:
            sys.exit("pipelined decode (oracle lookahead) is not "
                     "token-identical to serial")
        if not e2e["kernel_token_identical"]:
            sys.exit(f"auto ffn_kernel ({e2e['auto_ffn_kernel']}) is not "
                     "token-identical to the forced-bundles arm")
        floor = args.tolerance * el["serial_tokens_per_s"]
        if el["pipelined_tokens_per_s"] < floor:
            sys.exit(f"pipelined decode regressed: "
                     f"{el['pipelined_tokens_per_s']} tok/s < "
                     f"{args.tolerance} * serial ({floor:.1f})")
        fresh_eff = el["measured"]["overlap_efficiency"]
        if committed_eff is not None:
            eff_floor = args.efficiency_tolerance * committed_eff
            if fresh_eff < eff_floor:
                sys.exit(f"overlap efficiency regressed: {fresh_eff:.3f} < "
                         f"{args.efficiency_tolerance} x committed "
                         f"({committed_eff:.3f})")
        log.info("prefetch gate OK: pipelined %s tok/s vs serial %s "
                 "(%sx, emulated device latency, ffn_kernel=%s), oracle + "
                 "kernel token-identical e2e, overlap efficiency %.3f%s",
                 el["pipelined_tokens_per_s"], el["serial_tokens_per_s"],
                 el["improvement"], el["ffn_kernel"], fresh_eff,
                 (f" vs committed {committed_eff:.3f}"
                  if committed_eff is not None else ""))
        cont = serving["continuous"]["tokens_per_s"]
        grp = serving["grouped"]["tokens_per_s"]
        if cont < args.serving_tolerance * grp:
            sys.exit(f"continuous batching regressed: {cont:.1f} tok/s < "
                     f"{args.serving_tolerance} x grouped ({grp:.1f})")
        log.info("serving gate OK: continuous %.1f tok/s vs "
                 "length-grouped %.1f (%sx on the mixed-length Poisson "
                 "workload)", cont, grp, serving["speedup"])
        pk = serving["paged_kv"]
        conc, sp, pr = pk["concurrency"], pk["shared_prefix"], pk["pressure"]
        if conc["concurrency_ratio"] < args.paged_concurrency_floor:
            sys.exit(f"paged KV concurrency below floor: "
                     f"{conc['concurrency_ratio']}x < "
                     f"{args.paged_concurrency_floor}x at a "
                     f"{pk['budget']['kv_positions']}-position budget")
        if not (conc["tokens_identical"] and conc["all_finished_length"]):
            sys.exit("paged KV decode is not token-identical to the "
                     "contiguous-slot baseline")
        if conc["cow_copies"] != 0 or conc["preemptions"] != 0:
            sys.exit(f"paged clean path is not free: "
                     f"{conc['cow_copies']} CoW copies, "
                     f"{conc['preemptions']} preemptions on the "
                     f"unshared workload")
        if not sp["tokens_identical"] or sp["cow_copies"] < 1:
            sys.exit(f"shared-prefix CoW arm failed: identical="
                     f"{sp['tokens_identical']}, cow={sp['cow_copies']} "
                     f"(fork must diverge via copy-on-write)")
        if not (pr["preemptions"] > 0 and pr["finished_identical"]
                and pr["partial_prefix_identical"]
                and pr["pages_reclaimed"] and pr["alloc_freed_balanced"]):
            sys.exit(f"paged pressure arm failed: {pr}")
        log.info("paged KV gate OK: %s vs %s concurrent requests (%sx) "
                 "at the same %s-position KV budget, token-identical, clean "
                 "counters zero; CoW fork identical (%s copies); pressure "
                 "arm preempted %s with exact partial prefixes and full "
                 "page reclamation", conc["paged_peak_concurrent"],
                 conc["baseline_peak_concurrent"],
                 conc["concurrency_ratio"], pk["budget"]["kv_positions"],
                 sp["cow_copies"], pr["n_preempted"])


if __name__ == "__main__":
    main()
