"""Fault-tolerance benchmark: offload decode from a v2 NeuronPack under
seeded recoverable fault schedules, plus a worker-death supervision arm.

The claim under test (ISSUE 7 acceptance): fault tolerance is FREE when
nothing fails and EXACT when things do. Concretely:

  * clean arm — serving with retry + checksum verification armed but no
    faults injected reports zero `retries` / `corrupt_extents` /
    `degraded_steps` / `worker_restarts` (the counters themselves are the
    overhead gate);
  * seeded chaos arms — under per-layer schedules drawn at increasing fault
    rates (transient EIO + latency spikes + short reads + CRC-caught corrupt
    extents), decode output is TOKEN-IDENTICAL to the clean run and the
    counters equal the injected plan exactly: `retries == transient +
    corrupt`, `corrupt_extents == corrupt`;
  * worker-death arm — a FatalFault on a prefetch-worker read kills the
    worker thread; supervision restarts it and decode output is still
    token-identical.

Writes ``BENCH_faults.json``::

  {"meta": {...model/pack geometry...},
   "clean":  {"tokens_per_s", "retries", "corrupt_extents", ...},
   "chaos":  [{"rate", "injected": {...}, "retries", ..., "tokens_match"}],
   "pinned": {...the issue's exact schedule, >=1 corrupt extent per layer...},
   "worker_death": {"worker_restarts", "degraded_steps", "tokens_match"},
   "gates": {"clean_counters_zero", "all_tokens_identical",
             "counters_match_plan", "corrupt_extent_caught",
             "supervision_recovered"}}

Gates (``--check``, run in CI): every entry of `gates` must be true —
token identity and counter exactness are deterministic given the seeds;
wall-clock numbers are reported, never gated.

Run: PYTHONPATH=src python benchmarks/fault_bench.py [--quick] [--check] [--out F]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import time

import numpy as np

if __package__ in (None, ""):                     # standalone script mode
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import OffloadedFFNRuntime, Request, ServingEngine
from repro.store import (FaultEvent, FaultPlan, RetryPolicy, build_pack,
                         seeded_layer_plans)
from repro.utils import add_verbosity_flag, configure_logging, get_logger

log = get_logger("bench.faults")

RETRY = RetryPolicy(backoff_s=1e-4)     # real backoff shape, bench-friendly


def _workload(quick: bool) -> dict:
    d_ff = 192 if quick else 256
    n_req = 3 if quick else 4
    new_tokens = 8 if quick else 12
    cfg = get_config("opt-350m", reduced=True, d_model=48, d_ff=d_ff,
                     n_layers=2, vocab_size=128, activation="relu")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i, prompt=rng.integers(0, 128, 12).astype(np.int32),
                    max_new_tokens=new_tokens) for i in range(n_req)]
    return dict(cfg=cfg, model=model, params=params, reqs=reqs,
                meta=dict(quick=quick, d_model=48, d_ff=d_ff, n_layers=2,
                          requests=n_req, new_tokens=new_tokens))


def _serve(w: dict, pack_path, *, fault_plans=None, prefetch=False,
           verify=True) -> tuple:
    """One serving run from the pack; returns (tokens, io_summary, wall)."""
    rt = OffloadedFFNRuntime.from_pack(
        w["cfg"], pack_path, verify_checksums=verify,
        fault_plans=fault_plans, retry=RETRY)
    eng = ServingEngine(w["model"], w["params"], mode="offload", offload=rt,
                        prefetch=prefetch,
                        lookahead="oracle" if prefetch else None)
    try:
        t0 = time.perf_counter()
        results = eng.serve(w["reqs"])
        wall = time.perf_counter() - t0
        return [r.tokens for r in results], rt.io_summary(), wall
    finally:
        eng.close()
        rt.close()


def _counters(s: dict) -> dict:
    return {k: int(s[k]) for k in ("retries", "corrupt_extents",
                                   "degraded_steps", "worker_restarts")}


def run(quick: bool) -> dict:
    w = _workload(quick)
    n_tok = sum(r.max_new_tokens for r in w["reqs"]) + len(w["reqs"])
    rates = (0.05,) if quick else (0.02, 0.05, 0.1)
    report = {"meta": dict(w["meta"], chaos_rates=list(rates))}

    with tempfile.TemporaryDirectory(prefix="bench-faults-") as td:
        pack_path = pathlib.Path(td) / "m.npack"
        built = build_pack(w["model"], w["params"], pack_path,
                           calib_tokens=128, calib_batch=4, calib_seqlen=32)
        report["meta"]["pack_mb"] = round(built.file_bytes / 1e6, 2)

        # -- clean arm: machinery armed, nothing injected -------------------
        clean_tokens, s, wall = _serve(w, pack_path)
        clean = _counters(s)
        report["clean"] = dict(clean, tokens_per_s=round(n_tok / wall, 1),
                               io_ms_per_token=round(
                                   s["io_seconds_per_token"] * 1e3, 4))
        gate_clean = all(v == 0 for v in clean.values())

        # -- seeded chaos arms ----------------------------------------------
        report["chaos"] = []
        gate_tokens = gate_counters = True
        for rate in rates:
            plans = seeded_layer_plans(
                7, 2, 200, transient_rate=rate, latency_rate=rate / 2,
                delay_s=5e-4, short_read_rate=rate / 2, corrupt_rate=rate / 2)
            tokens, s, wall = _serve(w, pack_path, fault_plans=plans)
            inj = {k: sum(p.injected[k] for p in plans)
                   for k in FaultEvent.KINDS}
            match = tokens == clean_tokens
            exact = (s["retries"] == inj["transient"] + inj["corrupt"]
                     and s["corrupt_extents"] == inj["corrupt"])
            gate_tokens &= match
            gate_counters &= exact
            report["chaos"].append(dict(
                rate=rate, injected=inj, **_counters(s),
                tokens_per_s=round(n_tok / wall, 1),
                tokens_match=match, counters_exact=exact))

        # -- pinned acceptance arm: the issue's exact schedule --------------
        # (rate-drawn arms may dodge a kind entirely at low rates; this arm
        # guarantees >=1 CRC-caught corrupt extent per layer, every run)
        plans = [FaultPlan([FaultEvent(0, "transient"),
                            FaultEvent(1, "latency", delay_s=1e-3),
                            FaultEvent(2, "corrupt"),
                            FaultEvent(3, "short_read")], seed=11 + l)
                 for l in range(2)]
        tokens, s, wall = _serve(w, pack_path, fault_plans=plans)
        inj = {k: sum(p.injected[k] for p in plans) for k in FaultEvent.KINDS}
        pinned_match = tokens == clean_tokens
        pinned_exact = (s["retries"] == inj["transient"] + inj["corrupt"]
                        and s["corrupt_extents"] == inj["corrupt"])
        gate_tokens &= pinned_match
        gate_counters &= pinned_exact
        gate_corrupt = s["corrupt_extents"] >= 1
        report["pinned"] = dict(
            injected=inj, **_counters(s),
            tokens_per_s=round(n_tok / wall, 1),
            tokens_match=pinned_match, counters_exact=pinned_exact)

        # -- worker-death supervision arm -----------------------------------
        plans = [FaultPlan([FaultEvent(4, "fatal")], seed=5),
                 FaultPlan(seed=6)]
        tokens, s, wall = _serve(w, pack_path, fault_plans=plans,
                                 prefetch=True, verify=False)
        match = tokens == clean_tokens
        recovered = (s["worker_restarts"] >= 1 and s["degraded_steps"] >= 1
                     and plans[0].injected["fatal"] == 1)
        report["worker_death"] = dict(
            _counters(s), tokens_per_s=round(n_tok / wall, 1),
            tokens_match=match)

    report["gates"] = {
        "clean_counters_zero": gate_clean,
        "all_tokens_identical": bool(gate_tokens and match),
        "counters_match_plan": bool(gate_counters),
        "corrupt_extent_caught": bool(gate_corrupt),
        "supervision_recovered": bool(recovered),
    }
    return report


def fault_bench():
    """benchmarks/run.py suite entry: (name, us_per_call, derived) rows."""
    r = run(quick=True)
    rows = [("fault_bench/clean_tokens_per_s", r["clean"]["tokens_per_s"],
             "retry+verify armed, zero counters on the clean path")]
    for arm in r["chaos"]:
        inj = arm["injected"]
        rows.append((f"fault_bench/chaos_rate_{arm['rate']}_tokens_per_s",
                     arm["tokens_per_s"],
                     f"{arm['retries']} retries, {arm['corrupt_extents']} "
                     f"corrupt caught of {inj['transient']}+{inj['corrupt']} "
                     f"injected; tokens_match={arm['tokens_match']}"))
    p = r["pinned"]
    rows.append(("fault_bench/pinned_schedule_tokens_per_s",
                 p["tokens_per_s"],
                 f"{p['retries']} retries, {p['corrupt_extents']} CRC-caught "
                 f"corrupt extents; tokens_match={p['tokens_match']}"))
    wd = r["worker_death"]
    rows.append(("fault_bench/worker_death_tokens_per_s",
                 wd["tokens_per_s"],
                 f"{wd['worker_restarts']} restart(s), "
                 f"{wd['degraded_steps']} degraded steps; "
                 f"tokens_match={wd['tokens_match']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for the CI smoke run")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every gate holds: zero "
                         "counters on the clean path, token identity under "
                         "every recoverable schedule, counters exactly "
                         "matching the injected plans, and supervision "
                         "surviving the worker death")
    ap.add_argument("--out", default="BENCH_faults.json")
    add_verbosity_flag(ap)
    args = ap.parse_args()
    configure_logging(args.verbose)

    report = run(args.quick)
    pathlib.Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    if args.check:
        bad = [k for k, ok in report["gates"].items() if not ok]
        if bad:
            sys.exit(f"fault-tolerance gates failed: {', '.join(bad)}")
        log.info("fault gates OK: %s", ", ".join(report["gates"]))


if __name__ == "__main__":
    main()
