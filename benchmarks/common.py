"""Shared benchmark scaffolding.

The paper's I/O metrics depend on (n_neurons, bundle_bytes, sparsity, layout)
— all taken from Table 3. Activation traces are the planted-cluster synthetic
workload (core/trace.py) calibrated to each model's Table-3 sparsity; weights
are synthetic (payload values don't affect I/O metrics). Two layers per model
are simulated and per-token I/O scales linearly with layer count (layers are
independent, as the paper exploits for its offline parallelism).

Result row format: (name, us_per_call, derived).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.paper_models import PAPER_MODELS, PAPER_NEURONS, PAPER_SPARSITY
from repro.core import (EngineConfig, OffloadEngine, PlacementResult,
                        identity_placement, search_placement, stats_from_masks)
from repro.core.storage import UFS40, UFSDevice
from repro.core.trace import SyntheticTraceConfig, synthetic_masks

Row = Tuple[str, float, str]

N_CALIB_TOKENS = 300
N_SERVE_TOKENS = 120
N_SIM_LAYERS = 2
N_CLUSTERS = 64
BYTES_PER_PARAM = 2        # fp16, the paper's default precision


def model_geometry(model_id: str) -> Tuple[int, int, int, float, int]:
    """(n_neurons_per_block, n_mats, d_model, sparsity, n_layers)."""
    cfg = PAPER_MODELS[model_id]
    n, n_mats = PAPER_NEURONS[model_id]
    return n, n_mats, cfg.d_model, PAPER_SPARSITY[model_id], cfg.n_layers


def trace_config(model_id: str, layer: int = 0, seed: int = 0, zipf: float = 1.1,
                 popularity_seed: int = 0) -> SyntheticTraceConfig:
    """Cluster membership is keyed on (model, layer) — a MODEL property that
    calibration and serving share; token sampling + popularity are the
    'dataset' (paper Fig. 15)."""
    n, _, _, sparsity, _ = model_geometry(model_id)
    cpt = max(1, round(sparsity * N_CLUSTERS / 0.9))
    structure = abs(hash((model_id, layer))) % (2 ** 31)
    return SyntheticTraceConfig(
        n_neurons=n, n_clusters=N_CLUSTERS, clusters_per_token=min(cpt, N_CLUSTERS),
        member_p=0.9, noise_p=0.005, zipf_alpha=zipf, seed=seed,
        structure_seed=structure, popularity_seed=popularity_seed)


@dataclasses.dataclass
class SimModel:
    model_id: str
    calib: List[np.ndarray]          # per layer [T, n] masks
    serve: List[np.ndarray]
    bundles: np.ndarray              # [n, bundle_width] shared across sim layers
    n_mats: int
    n_layers_real: int

    @property
    def n_neurons(self) -> int:
        return self.bundles.shape[0]


_SIM_CACHE: Dict[Tuple, SimModel] = {}


def build_sim_model(model_id: str, calib_seed: int = 0, serve_seed: int = 1000,
                    zipf: float = 1.1, serve_zipf: Optional[float] = None,
                    calib_pop: int = 0, serve_pop: int = 0) -> SimModel:
    key = (model_id, calib_seed, serve_seed, zipf, serve_zipf, calib_pop, serve_pop)
    if key in _SIM_CACHE:
        return _SIM_CACHE[key]
    n, n_mats, d, sparsity, L = model_geometry(model_id)
    calib, serve = [], []
    for layer in range(N_SIM_LAYERS):
        calib.append(synthetic_masks(
            trace_config(model_id, layer, seed=calib_seed + layer, zipf=zipf,
                         popularity_seed=calib_pop), N_CALIB_TOKENS))
        serve.append(synthetic_masks(
            trace_config(model_id, layer, seed=serve_seed + layer,
                         zipf=serve_zipf if serve_zipf is not None else zipf,
                         popularity_seed=serve_pop), N_SERVE_TOKENS))
    # synthetic fp16 payloads: [n, n_mats * d]
    bundles = np.zeros((n, n_mats * d), dtype=np.float16)
    sim = SimModel(model_id=model_id, calib=calib, serve=serve, bundles=bundles,
                   n_mats=n_mats, n_layers_real=L)
    _SIM_CACHE[key] = sim
    return sim


_PLACEMENT_CACHE: Dict[Tuple, List[PlacementResult]] = {}


def ripple_placements(sim: SimModel, key_extra: Tuple = ()) -> List[PlacementResult]:
    key = (sim.model_id, id(sim)) + key_extra
    if key in _PLACEMENT_CACHE:
        return _PLACEMENT_CACHE[key]
    placements = []
    for masks in sim.calib:
        stats = stats_from_masks(masks)
        placements.append(search_placement(stats.distance_matrix(), mode="auto"))
    _PLACEMENT_CACHE[key] = placements
    return placements


# -- the three systems under comparison --------------------------------------

def make_engines(sim: SimModel, system: str, device: Optional[UFSDevice] = None,
                 cache_ratio: float = 0.1) -> List[OffloadEngine]:
    """system: llama.cpp | llmflash | ripple | ripple-offline | ripple-online."""
    n = sim.n_neurons
    device = device or UFSDevice(**UFS40)
    if system == "llama.cpp":
        cfg = EngineConfig(cache_ratio=cache_ratio, collapse=False,
                           linking_aligned_cache=False, reads_per_bundle=sim.n_mats)
        pls = [identity_placement(n) for _ in range(N_SIM_LAYERS)]
    elif system == "llmflash":    # row-column bundling, S3-FIFO, structure layout
        cfg = EngineConfig(cache_ratio=cache_ratio, collapse=False,
                           linking_aligned_cache=False, reads_per_bundle=1)
        pls = [identity_placement(n) for _ in range(N_SIM_LAYERS)]
    elif system == "ripple-offline":   # placement only
        cfg = EngineConfig(cache_ratio=cache_ratio, collapse=False,
                           linking_aligned_cache=False, reads_per_bundle=1)
        pls = ripple_placements(sim)
    elif system == "ripple-online":    # collapse + cache policy only
        cfg = EngineConfig(cache_ratio=cache_ratio, collapse=True,
                           linking_aligned_cache=True, reads_per_bundle=1)
        pls = [identity_placement(n) for _ in range(N_SIM_LAYERS)]
    elif system == "ripple":
        cfg = EngineConfig(cache_ratio=cache_ratio, collapse=True,
                           linking_aligned_cache=True, reads_per_bundle=1)
        pls = ripple_placements(sim)
    else:
        raise ValueError(system)
    return [OffloadEngine(sim.bundles, placement=pl, device=device, config=cfg)
            for pl in pls]


def serve_and_summarise(sim: SimModel, system: str, device: Optional[UFSDevice] = None,
                        cache_ratio: float = 0.1) -> Dict[str, float]:
    engines = make_engines(sim, system, device, cache_ratio)
    for eng, masks in zip(engines, sim.serve):
        eng.run_trace(masks)
    per_layer = [e.summary() for e in engines]
    scale = sim.n_layers_real / N_SIM_LAYERS
    return {
        "io_s_per_token": sum(s["io_seconds_per_token"] for s in per_layer) * scale,
        "effective_bandwidth": float(np.mean([s["effective_bandwidth"] for s in per_layer])),
        "raw_bandwidth": float(np.mean([s["raw_bandwidth"] for s in per_layer])),
        "iops": float(np.mean([s["iops"] for s in per_layer])),
        "ops_per_token": sum(s["ops_per_token"] for s in per_layer) * scale,
        "mean_run_length": float(np.mean([s["mean_run_length"] for s in per_layer])),
        "max_run_length": int(max(s["max_run_length"] for s in per_layer)),
        "waste_ratio": float(np.mean([s["waste_ratio"] for s in per_layer])),
        "cache_hit_rate": float(np.mean([s["cache_hit_rate"] for s in per_layer])),
        "bytes_per_token": sum(
            sum(t.io.bytes_read for t in e.history) / max(len(e.history), 1)
            for e in engines) * scale,
    }


def timed_rows(fn, name: str) -> Tuple[List[Row], float]:
    t0 = time.perf_counter()
    rows = fn()
    return rows, time.perf_counter() - t0
