"""Benchmark entry point. One function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (and a trailing wall-time line per
suite). Run: PYTHONPATH=src python -m benchmarks.run [--only substr]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on suite name")
    args = ap.parse_args()

    from benchmarks import (fault_bench, kernel_bench, load_harness,
                            moe_expert_bench, obs_overhead, pack_io,
                            paper_figures, roofline, serving_pipeline)

    suites = [
        ("fig4_bandwidth", paper_figures.fig4_bandwidth),
        ("table1_breakdown", paper_figures.table1_breakdown),
        ("fig5_sparsity_latency", paper_figures.fig5_sparsity_latency),
        ("fig10_overall", paper_figures.fig10_overall),
        ("fig11_breakdown", paper_figures.fig11_breakdown),
        ("fig12_access_length", paper_figures.fig12_access_length),
        ("table4_search_time", paper_figures.table4_search_time),
        ("fig13_collapse", paper_figures.fig13_collapse),
        ("fig14_cache_ratio", paper_figures.fig14_cache_ratio),
        ("fig15_sensitivity", paper_figures.fig15_sensitivity),
        ("fig16_hardware", paper_figures.fig16_hardware),
        ("fig17_precision", paper_figures.fig17_precision),
        ("serving_pipeline", serving_pipeline.serving_pipeline),
        ("pack_io", pack_io.pack_io),
        ("fault_bench", fault_bench.fault_bench),
        ("load_harness", load_harness.load_harness),
        ("obs_overhead", obs_overhead.obs_overhead),
        ("kernels", kernel_bench.kernel_bench),
        ("moe_expert", moe_expert_bench.moe_expert_bench),
        ("roofline", roofline.rows_for_run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},NaN,ERROR: {e!r}", flush=True)
            continue
        for rname, val, derived in rows:
            print(f'{rname},{val:.3f},"{derived}"', flush=True)
        print(f'{name}/_suite_seconds,{(time.perf_counter()-t0)*1e6:.0f},"wall time"',
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
