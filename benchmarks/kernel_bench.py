"""Kernel micro-benchmarks: interpret-mode timing + DMA-descriptor accounting.

Wall time on CPU interpret mode is NOT TPU performance; the structurally
meaningful number is the DMA-descriptor count per call (segments x matrices),
which is exactly the IOPS quantity RIPPLE minimises at the HBM tier.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

Row = Tuple[str, float, str]


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw).block_until_ready()       # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_bench() -> List[Row]:
    rng = np.random.default_rng(0)
    rows: List[Row] = []

    B, D, N, seg = 8, 512, 2048, 128
    x = jnp.asarray(rng.standard_normal((B, D)) * 0.3, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((N, D)) * 0.05, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((N, D)) * 0.05, jnp.float32)
    for n_seg in (2, 8):
        ids = jnp.arange(n_seg, dtype=jnp.int32)
        us = _time(ops.sparse_ffn_segments, x, wu, wd, ids, seg_size=seg)
        rows.append((f"kernels/sparse_ffn/segs_{n_seg}", us,
                     f"interpret-us; dma_descriptors={n_seg * 2} "
                     f"(vs {n_seg * seg * 2} per-neuron scattered)"))

    # fused-vs-unfused arm: same covered-neuron count served either as few
    # contiguous segments (linked layout) or as many scattered ones. int8
    # tiles quarter the HBM->VMEM weight bytes per descriptor; the scale
    # tiles add S*seg*4 bytes (one f32 row per segment).
    q8u = jnp.asarray(rng.integers(-127, 128, (N, D)), jnp.int8)
    q8d = jnp.asarray(rng.integers(-127, 128, (N, D)), jnp.int8)
    scales = rng.random(N).astype(np.float32) * 0.01
    for layout, n_seg in (("linked", 2), ("scattered", 8)):
        covered = 2 * seg                      # equal work in both layouts
        if layout == "linked":
            ids_np = np.arange(n_seg, dtype=np.int32)
            live = np.arange(covered)
        else:                                  # same neurons/segment count
            ids_np = np.arange(0, n_seg * 2, 2, dtype=np.int32)
            live = (ids_np[:, None] * seg
                    + np.arange(covered // n_seg)[None, :]).ravel()
        tiles = np.zeros((ids_np.size, seg), np.float32)
        tiles[np.searchsorted(ids_np, live // seg), live % seg] = scales[live]
        ids = jnp.asarray(ids_np)
        tls = jnp.asarray(tiles)
        us = _time(ops.sparse_ffn_segments_fused, x, wu, wd, ids, tls,
                   interpret=True, seg_size=seg)
        rows.append((f"kernels/sparse_ffn_fused/f32_{layout}", us,
                     f"interpret-us; dma_descriptors={ids_np.size * 2 + ids_np.size}"
                     f" weight_bytes={ids_np.size * seg * D * 4 * 2}"))
        us = _time(ops.sparse_ffn_segments_fused, x, q8u, q8d, ids, tls,
                   interpret=True, seg_size=seg)
        rows.append((f"kernels/sparse_ffn_fused/int8_{layout}", us,
                     f"interpret-us; dma_descriptors={ids_np.size * 2 + ids_np.size}"
                     f" weight_bytes={ids_np.size * seg * D * 2}"
                     f" (4x fewer HBM->VMEM bytes than f32)"))

    m = jnp.asarray((rng.random((512, 1024)) < 0.2), jnp.float32)
    us = _time(ops.coact_accumulate, m, tile_n=256, tile_t=256)
    rows.append(("kernels/coact/512x1024", us, "interpret-us; A+=M^T M tiles=4x4x2"))

    B, H, KV, hd, W = 2, 8, 2, 128, 2048
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, W, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, W, KV, hd)), jnp.float32)
    pos = jnp.asarray(np.arange(W)[None].repeat(B, 0), jnp.int32)
    us = _time(ops.swa_decode_attention, q, k, v, pos, jnp.int32(W - 1),
               window=1024, block_w=512)
    rows.append(("kernels/swa_decode/W2048", us,
                 "interpret-us; online-softmax blocks=4/head"))
    return rows
