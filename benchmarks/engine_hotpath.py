"""Online hot-path microbenchmarks: array-native cache vs the dict reference.

Three sections, all on the paper-style planted-cluster workload (Table-3-ish
geometry: ~4k activated neurons per step out of ~40k, cache_ratio 0.1):

  * cache_probe_admit — the core tentpole number: per-step latency of
    `lookup` + `admit` for `ArrayLinkingAlignedCache` vs the reference
    `LinkingAlignedCache`, on (a) the linked layout (cluster-contiguous
    physical placement, i.e. what the engine serves after the co-activation
    search) and (b) the scattered identity-style layout. Decision
    equivalence between the two implementations is asserted while timing.
  * engine_step — `OffloadEngine.step_masks` with the array cache vs the
    legacy `step_batch`-over-id-lists with the dict cache, one decode batch
    per step.
  * serving_decode — host wall-clock decode throughput of the engine-driven
    layerwise loop (N layers x T tokens x batch B), vectorized vs reference.
  * ffn_kernel — the REAL FFN compute attached: OffloadedFFNRuntime's
    bundles path vs the fused segment kernel on the linked layout, reporting
    host glue_us_per_step (staging + dispatch + compute wall) against
    modeled_io_us_per_step (the UFS device model for the same steps). The
    ISSUE 6 acceptance reads from here: on the linked layout the segments
    path must be modeled-I/O-bound, not glue-bound.

Writes a machine-readable ``BENCH_hotpath.json``:

  {"meta": {...workload geometry...},
   "cache_probe_admit": {"linked": {"dict_us_per_step", "array_us_per_step",
                                    "speedup"}, "scattered": {...}},
   "engine_step": {"reference_us_per_step", "vectorized_us_per_step",
                   "speedup"},
   "serving_decode": {"reference_tokens_per_s", "vectorized_tokens_per_s",
                      "improvement"},
   "ffn_kernel": {"bundles": {"glue_us_per_step", "modeled_io_us_per_step",
                              "glue_share", "modeled_io_share"},
                  "segments": {...}, "auto_selected", "auto_reason",
                  "outputs_allclose", "segments_glue_lt_modeled_io"},
   "counters": {"array_probe_iters", "array_classify_iters",
                "array_sample_iters", "array_fallback_batches",
                "dict_per_neuron_iters"},
   "equivalence_checked": true}

The CI perf smoke runs ``--quick`` and gates on the counters (exactly zero
per-neuron Python-loop iterations on the array path), not on wall-clock —
timing on shared runners is informative, not a pass/fail signal.

Run: PYTHONPATH=src python benchmarks/engine_hotpath.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

if __package__ in (None, ""):                     # standalone script mode
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.core.cache import ArrayLinkingAlignedCache, LinkingAlignedCache
from repro.core.engine import EngineConfig, OffloadEngine
from repro.core.placement import PlacementResult
from repro.core.trace import SyntheticTraceConfig, synthetic_masks
from repro.utils import add_verbosity_flag, configure_logging, get_logger

log = get_logger("bench.hotpath")


def _workload(quick: bool):
    """Planted-cluster trace + the two physical layouts under test."""
    n_neurons = 8192 if quick else 40960
    n_clusters = 64
    cpt = 7                                       # ~0.1 sparsity -> ~4k of 40k
    steps = 24 if quick else 60
    warm = 8 if quick else 20
    tc = SyntheticTraceConfig(n_neurons=n_neurons, n_clusters=n_clusters,
                              clusters_per_token=cpt, member_p=0.9,
                              noise_p=0.005, zipf_alpha=1.1, seed=0,
                              structure_seed=0)
    masks = synthetic_masks(tc, steps)
    # linked layout: clusters contiguous in flash — the layout the offline
    # co-activation search produces (structure recovered from the generator's
    # seeding, so the bench doesn't pay for a 40k-neuron placement search)
    struct_rng = np.random.default_rng(0)
    perm = struct_rng.permutation(n_neurons)
    cluster_of = np.empty(n_neurons, dtype=np.int64)
    for c in range(n_clusters):
        cluster_of[perm[c::n_clusters]] = c
    linked_order = np.argsort(cluster_of, kind="stable")
    linked_inv = np.empty(n_neurons, dtype=np.int64)
    linked_inv[linked_order] = np.arange(n_neurons)
    scattered_inv = np.random.default_rng(1).permutation(n_neurons)
    return dict(n_neurons=n_neurons, masks=masks, warm=warm,
                linked_inv=linked_inv, scattered_inv=scattered_inv,
                linked_order=linked_order)


def _drive_cache(cache, ids_trace, inverse, lo, hi):
    for ids in ids_trace[lo:hi]:
        hit = cache.lookup_mask(ids)
        misses = ids[~hit]
        cache.admit(misses, inverse[misses])


def bench_cache(w, layout: str, repeats: int) -> dict:
    """Per-step probe+admit latency, dict vs array, decision-equivalent."""
    inverse = w[f"{layout}_inv"]
    n = w["n_neurons"]
    cap = int(0.1 * n)
    ids_trace = [np.flatnonzero(m) for m in w["masks"]]
    warm = w["warm"]

    # equivalence pass (not timed): identical decisions, step by step
    ref = LinkingAlignedCache(cap)
    arr = ArrayLinkingAlignedCache(cap, n)
    for t, ids in enumerate(ids_trace):
        m1, m2 = ref.lookup_mask(ids), arr.lookup_mask(ids)
        assert np.array_equal(m1, m2), f"hit-mask divergence at step {t}"
        misses = ids[~m1]
        ref.admit(misses, inverse[misses])
        arr.admit(misses, inverse[misses])
        assert ref.cache.queues() == arr.cache.queues(), \
            f"queue divergence at step {t}"
    counters = dict(
        array_probe_iters=arr.loop_counters.probe,
        array_classify_iters=arr.loop_counters.classify,
        array_sample_iters=arr.loop_counters.sample,
        array_fallback_batches=arr.loop_counters.fallback_batches,
        dict_per_neuron_iters=ref.loop_counters.per_neuron_total,
    )

    def timed_once(make):
        cache = make()
        _drive_cache(cache, ids_trace, inverse, 0, warm)          # warm cache
        t0 = time.perf_counter()
        _drive_cache(cache, ids_trace, inverse, warm, len(ids_trace))
        return (time.perf_counter() - t0) / (len(ids_trace) - warm)

    # paired repeats: the two implementations are timed back to back inside
    # each repeat so host-load drift cancels out of the ratio; report the
    # fastest pair
    pairs = [(timed_once(lambda: LinkingAlignedCache(cap)),
              timed_once(lambda: ArrayLinkingAlignedCache(cap, n)))
             for _ in range(repeats)]
    dict_us, array_us = min(pairs, key=lambda p: p[0] + p[1])
    dict_us, array_us = dict_us * 1e6, array_us * 1e6
    return dict(dict_us_per_step=round(dict_us, 1),
                array_us_per_step=round(array_us, 1),
                speedup=round(dict_us / array_us, 2)), counters


def _batch_masks(w, batch: int):
    """One decode batch per step: `batch` shifted mask streams in lockstep."""
    masks = w["masks"]
    offset = 7
    out = []
    for t in range(len(masks)):
        rows = [(t + r * offset) % len(masks) for r in range(batch)]
        out.append(masks[rows])
    return out


def _linked_placement(w) -> PlacementResult:
    order = w["linked_order"]
    inv = w["linked_inv"]
    return PlacementResult(placement=order, inverse=inv, edges_used=0,
                           search_seconds=0.0, mode="bench-linked")


def bench_engine_step(w, repeats: int, batch: int = 4) -> dict:
    """Vectorized step_masks (array cache) vs per-request step_batch (dict)."""
    n = w["n_neurons"]
    rng = np.random.default_rng(2)
    bundles = rng.standard_normal((n, 32)).astype(np.float32)
    pl = _linked_placement(w)
    batches = _batch_masks(w, batch)
    warm = w["warm"]

    def run_vectorized():
        eng = OffloadEngine(bundles, placement=pl,
                            config=EngineConfig(cache_impl="array"))
        for b in batches[:warm]:
            eng.step_masks(b)
        t0 = time.perf_counter()
        for b in batches[warm:]:
            eng.step_masks(b)
        return (time.perf_counter() - t0) / (len(batches) - warm)

    def run_reference():
        eng = OffloadEngine(bundles, placement=pl,
                            config=EngineConfig(cache_impl="dict"))
        for b in batches[:warm]:
            eng.step_batch([np.flatnonzero(r) for r in b])
        t0 = time.perf_counter()
        for b in batches[warm:]:
            eng.step_batch([np.flatnonzero(r) for r in b])
        return (time.perf_counter() - t0) / (len(batches) - warm)

    vec = min(run_vectorized() for _ in range(repeats)) * 1e6
    ref = min(run_reference() for _ in range(repeats)) * 1e6
    return dict(reference_us_per_step=round(ref, 1),
                vectorized_us_per_step=round(vec, 1),
                speedup=round(ref / vec, 2))


def bench_serving_decode(w, repeats: int, batch: int = 4,
                         n_layers: int = 2) -> dict:
    """Host wall-clock decode tokens/sec of the engine-driven layer loop."""
    n = w["n_neurons"]
    rng = np.random.default_rng(3)
    bundles = rng.standard_normal((n, 32)).astype(np.float32)
    pl = _linked_placement(w)
    batches = _batch_masks(w, batch)
    warm = w["warm"]

    def run(impl: str):
        engines = [OffloadEngine(bundles, placement=pl,
                                 config=EngineConfig(cache_impl=impl))
                   for _ in range(n_layers)]
        def token(b):
            for eng in engines:
                if impl == "array":
                    eng.step_masks(b)
                else:
                    eng.step_batch([np.flatnonzero(r) for r in b])
        for b in batches[:warm]:
            token(b)
        t0 = time.perf_counter()
        for b in batches[warm:]:
            token(b)
        return (len(batches) - warm) * batch / (time.perf_counter() - t0)

    vec = max(run("array") for _ in range(repeats))
    ref = max(run("dict") for _ in range(repeats))
    return dict(reference_tokens_per_s=round(ref, 1),
                vectorized_tokens_per_s=round(vec, 1),
                improvement=round(vec / ref, 2))


def bench_ffn_kernel(w, repeats: int, batch: int = 8, d: int = 128) -> dict:
    """Glue vs modeled I/O with the REAL FFN compute attached: bundles path
    vs the fused segment kernel, linked layout, one dense-FFN layer.

    glue_us_per_step is the measured host wall per decode step (cache probe +
    staging gather + kernel dispatch + compute); modeled_io_us_per_step is
    what the UFS device model bills for the same steps' flash reads (at
    bundle_bytes=8192, a phone-scale row). Equal modeled I/O across arms is
    asserted by construction (the kernel choice never changes accounting);
    output agreement is checked while timing.
    """
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.serving.engine import OffloadedFFNRuntime

    n = w["n_neurons"]
    rng = np.random.default_rng(4)
    bundles = (rng.standard_normal((n, 2 * d)).astype(np.float32) * 0.05)
    pl = _linked_placement(w)
    cfg = get_config("opt-350m", reduced=True, d_model=d, d_ff=n,
                     vocab_size=256)
    batches = _batch_masks(w, batch)
    warm = w["warm"]
    h = jnp.asarray(rng.standard_normal((batch, d)).astype(np.float32) * 0.3)
    out, ys = {}, {}
    for kernel in ("bundles", "segments"):
        def run():
            rt = OffloadedFFNRuntime(
                cfg, [bundles], [pl], bundle_bytes=8192,
                engine_cfg=EngineConfig(ffn_kernel=kernel))
            for b in batches[:warm]:
                y, _ = rt.ffn_apply_batch(0, h, b)
            y.block_until_ready()
            rt.reset_stats()
            io = 0.0
            t0 = time.perf_counter()
            for b in batches[warm:]:
                y, res = rt.ffn_apply_batch(0, h, b)
                y.block_until_ready()
                io += res.merged.io.seconds
            steps = len(batches) - warm
            return ((time.perf_counter() - t0) / steps, io / steps, y)
        glue_s, io_s, y = min((run() for _ in range(repeats)),
                              key=lambda r: r[0])
        ys[kernel] = np.asarray(y)
        glue_us, io_us = glue_s * 1e6, io_s * 1e6
        out[kernel] = dict(
            glue_us_per_step=round(glue_us, 1),
            modeled_io_us_per_step=round(io_us, 1),
            glue_share=round(glue_us / (glue_us + io_us), 3),
            modeled_io_share=round(io_us / (glue_us + io_us), 3))
    rt_auto = OffloadedFFNRuntime(cfg, [bundles], [pl], bundle_bytes=8192)
    out["auto_selected"] = rt_auto.ffn_kernel
    out["auto_reason"] = rt_auto.ffn_kernel_reason
    out["outputs_allclose"] = bool(np.allclose(
        ys["bundles"], ys["segments"], rtol=1e-4, atol=1e-4))
    out["segments_glue_lt_modeled_io"] = bool(
        out["segments"]["glue_us_per_step"]
        < out["segments"]["modeled_io_us_per_step"])
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for the CI smoke run")
    ap.add_argument("--check-counters", action="store_true",
                    help="exit non-zero unless the array path ran with ZERO "
                         "per-neuron Python-loop iterations and zero "
                         "sequential-replay fallbacks (the CI gate — "
                         "deterministic, unlike wall-clock)")
    ap.add_argument("--out", default="BENCH_hotpath.json")
    add_verbosity_flag(ap)
    args = ap.parse_args()
    configure_logging(args.verbose)
    repeats = 1 if args.quick else 3
    w = _workload(args.quick)

    linked, counters = bench_cache(w, "linked", repeats)
    scattered, counters_scattered = bench_cache(w, "scattered", repeats)
    # the counter gate must cover BOTH layouts: the scattered one is the more
    # likely to hit an eviction corner that could knock the array cache off
    # its vectorized path
    for k, v in counters_scattered.items():
        counters[k] += v
    engine_step = bench_engine_step(w, repeats)
    serving = bench_serving_decode(w, repeats)
    ffn_kernel = bench_ffn_kernel(w, repeats)

    report = {
        "meta": {
            "quick": args.quick,
            "n_neurons": w["n_neurons"],
            "cache_ratio": 0.1,
            "mean_activated": round(float(np.mean(
                [m.sum() for m in w["masks"]])), 1),
            "steps": len(w["masks"]),
            "warmup_steps": w["warm"],
            "repeats": repeats,
        },
        "cache_probe_admit": {"linked": linked, "scattered": scattered},
        "engine_step": engine_step,
        "serving_decode": serving,
        "ffn_kernel": ffn_kernel,
        "counters": counters,
        "equivalence_checked": True,
    }
    pathlib.Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    if args.check_counters:
        bad = {k: v for k, v in counters.items()
               if k.startswith("array_") and v != 0}
        if bad:
            sys.exit(f"per-neuron loop counters regressed on the array "
                     f"hot path: {bad}")
        # deterministic (non-wall-clock) parts of the ffn_kernel section
        # gate too: the fused segment path must agree with bundles, and
        # "auto" must promote it on this linked layout
        if not ffn_kernel["outputs_allclose"]:
            sys.exit("segments-vs-bundles FFN outputs diverged")
        if ffn_kernel["auto_selected"] != "segments":
            sys.exit(f"auto did not promote segments on the linked layout: "
                     f"{ffn_kernel['auto_reason']}")
        log.info("counter gate OK: array hot path ran fully vectorized; "
                 "ffn kernel equivalence OK")


if __name__ == "__main__":
    main()
