"""Observability overhead benchmark: prove tracing is free when off and
cheap when on, without perturbing decode output.

Two serving arms run the SAME offload+prefetch workload (interleaved
repeats so drift hits both arms equally):

  * disabled — the default ``NULL_TRACER`` is installed; every call site
    still calls ``get_tracer().span(...)`` (call sites never branch), so
    the cost of the *disabled* path is exactly the no-op call overhead;
  * enabled  — ``enable_tracing()`` records every span/instant/counter
    into per-thread rings and the run exports a Perfetto-loadable trace.

Writes ``BENCH_obs.json``::

  {"meta": {...workload geometry...},
   "null_call_ns":        per-call cost of a disabled span (microbenched),
   "events_per_step":     trace events emitted per server step when on,
   "disabled": {"median_step_ms", "overhead_pct"},   # modeled: calls x cost
   "enabled":  {"median_step_ms", "overhead_pct",    # measured: median ratio
                "n_events", "dropped"},
   "trace":    {"prefetch_spans", "decode_steps", "overlap_shown"},
   "gates": {"disabled_under_1pct", "enabled_under_5pct",
             "tokens_identical", "overlap_shown"}}

Gates (``--check``, run in CI):

  * disabled overhead < 1% of median step time — modeled as
    events_per_step x microbenched null-call cost, which upper-bounds the
    real cost (instants/counters are cheaper than spans);
  * enabled overhead < 5% — measured as the enabled/disabled median step
    ratio over interleaved repeats;
  * decode tokens byte-identical between arms and across repeats;
  * the exported trace SHOWS the overlap: at least one prefetch-worker
    read span intersects a serving-thread decode_step span in wall time.

Run: PYTHONPATH=src python benchmarks/obs_overhead.py \
        [--quick] [--check] [--out F] [--trace-out F]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import time

import numpy as np

if __package__ in (None, ""):                     # standalone script mode
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.obs import (NULL_TRACER, disable_tracing, enable_tracing,
                       set_tracer)
from repro.serving.engine import Request, build_offload_runtime
from repro.serving.server import InferenceServer
from repro.utils import add_verbosity_flag, configure_logging, get_logger

log = get_logger("bench.obs")


def _workload(quick: bool) -> dict:
    d_model = 96 if quick else 192
    d_ff = 512 if quick else 2048
    n_req = 2 if quick else 3
    new_tokens = 8 if quick else 16
    cfg = get_config("opt-350m", reduced=True, d_model=d_model, d_ff=d_ff,
                     n_layers=2, vocab_size=256, activation="relu")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return dict(cfg=cfg, model=model, params=params, n_req=n_req,
                new_tokens=new_tokens,
                meta=dict(quick=quick, d_model=d_model, d_ff=d_ff,
                          n_layers=2, requests=n_req, new_tokens=new_tokens))


def _requests(w: dict) -> list:
    rng = np.random.default_rng(1)
    return [Request(uid=i, prompt=rng.integers(0, 256, 8).astype(np.int32),
                    max_new_tokens=w["new_tokens"])
            for i in range(w["n_req"])]


def _run_arm(w: dict) -> tuple[list, list, int]:
    """One serving run under whatever tracer is installed.

    Returns (token lists, per-step wall seconds, decode steps).
    """
    rng = np.random.default_rng(7)
    rt = build_offload_runtime(w["model"], w["params"], rng=rng,
                               train_lookahead=True)
    server = InferenceServer(w["model"], w["params"], max_slots=2, max_len=64,
                             mode="offload", offload=rt, prefetch=True)
    handles = [server.submit(r) for r in _requests(w)]
    steps = []
    try:
        while server.has_work:
            t0 = time.perf_counter()
            server.step()
            steps.append(time.perf_counter() - t0)
        return ([list(h.tokens) for h in handles], steps,
                server.stats.decode_steps)
    finally:
        server.close()


def _null_call_ns(n: int = 20000) -> float:
    """Per-call cost of a span on the disabled (NULL_TRACER) path."""
    tr = NULL_TRACER
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("x", a=1):
            pass
    return (time.perf_counter() - t0) / n * 1e9


def run(quick: bool, trace_out: str | None = None) -> dict:
    w = _workload(quick)
    repeats = 2 if quick else 3
    report = {"meta": dict(w["meta"], repeats=repeats)}

    # Interleave the arms so clock drift / cache warmup hits both equally.
    # Repeat 0 is warmup (JIT compile lands there) and is excluded from
    # the timing pools but still token-checked.
    dis_steps, en_steps = [], []
    tokens_ref = None
    tokens_ok = True
    n_events = dropped = decode_steps = 0
    trace_events = []
    for rep in range(repeats + 1):
        def _disabled():
            set_tracer(NULL_TRACER)
            return _run_arm(w)[:2]

        def _enabled():
            nonlocal n_events, dropped, decode_steps, trace_events
            tracer = enable_tracing()
            try:
                toks, steps, decode_steps = _run_arm(w)
                n_events, dropped = tracer.n_events, tracer.dropped
                if rep == repeats:      # keep the last enabled trace
                    trace_events = tracer.export(trace_out) if trace_out \
                        else tracer.events()
                return toks, steps
            finally:
                disable_tracing()

        # alternate arm order per repeat so warmup bias cancels
        if rep % 2 == 0:
            (toks_d, steps_d), (toks_e, steps_e) = _disabled(), _enabled()
        else:
            (toks_e, steps_e), (toks_d, steps_d) = _enabled(), _disabled()

        if tokens_ref is None:
            tokens_ref = toks_d
        tokens_ok &= (toks_d == tokens_ref and toks_e == tokens_ref)
        if rep > 0:
            dis_steps += steps_d
            en_steps += steps_e

    med_d = statistics.median(dis_steps)
    med_e = statistics.median(en_steps)
    n_steps = max(1, len(en_steps) // repeats)
    events_per_step = n_events / n_steps
    null_ns = _null_call_ns()

    disabled_pct = events_per_step * null_ns * 1e-9 / med_d * 100.0
    enabled_pct = (med_e / med_d - 1.0) * 100.0

    pf = [(e["ts"], e["ts"] + e["dur"], e["tid"]) for e in trace_events
          if e.get("name") == "prefetch" and e.get("ph") == "X"]
    ds = [(e["ts"], e["ts"] + e["dur"], e["tid"]) for e in trace_events
          if e.get("name") == "decode_step" and e.get("ph") == "X"]
    overlap = any(p[0] < d[1] and d[0] < p[1] and p[2] != d[2]
                  for p in pf for d in ds)

    report["null_call_ns"] = round(null_ns, 1)
    report["events_per_step"] = round(events_per_step, 1)
    report["disabled"] = dict(median_step_ms=round(med_d * 1e3, 3),
                              overhead_pct=round(disabled_pct, 4))
    report["enabled"] = dict(median_step_ms=round(med_e * 1e3, 3),
                             overhead_pct=round(enabled_pct, 3),
                             n_events=int(n_events), dropped=int(dropped))
    report["trace"] = dict(prefetch_spans=len(pf), decode_steps=len(ds),
                           overlap_shown=bool(overlap))
    report["gates"] = {
        "disabled_under_1pct": bool(disabled_pct < 1.0),
        "enabled_under_5pct": bool(enabled_pct < 5.0),
        "tokens_identical": bool(tokens_ok),
        "overlap_shown": bool(overlap),
    }
    return report


def obs_overhead():
    """benchmarks/run.py suite entry: (name, us_per_call, derived) rows."""
    r = run(quick=True)
    return [
        ("obs_overhead/null_call_ns", r["null_call_ns"] / 1e3,
         "disabled get_tracer().span() per-call cost (value in ns/1000)"),
        ("obs_overhead/disabled_overhead_pct", r["disabled"]["overhead_pct"],
         f"{r['events_per_step']} events/step x null-call cost vs "
         f"{r['disabled']['median_step_ms']} ms median step"),
        ("obs_overhead/enabled_overhead_pct", r["enabled"]["overhead_pct"],
         f"median step {r['enabled']['median_step_ms']} ms with tracing on; "
         f"tokens_identical={r['gates']['tokens_identical']}, "
         f"overlap_shown={r['gates']['overlap_shown']}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for the CI smoke run")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every gate holds: disabled "
                         "overhead <1%% of step time, enabled <5%%, tokens "
                         "byte-identical between arms, and the trace showing "
                         "prefetch reads overlapping decode compute")
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="also export the last enabled-arm trace as "
                         "Perfetto/Chrome JSON (open at ui.perfetto.dev)")
    add_verbosity_flag(ap)
    args = ap.parse_args()
    configure_logging(args.verbose)

    report = run(args.quick, trace_out=args.trace_out)
    pathlib.Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    if args.trace_out:
        log.info("trace written to %s (open at https://ui.perfetto.dev)",
                 args.trace_out)
    if args.check:
        bad = [k for k, ok in report["gates"].items() if not ok]
        if bad:
            sys.exit(f"observability gates failed: {', '.join(bad)}")
        log.info("observability gates OK: %s", ", ".join(report["gates"]))


if __name__ == "__main__":
    main()
